//! Residual blocks (ResNet skip connections, He et al. [9]).
//!
//! `y = relu(main(x) + shortcut(x))`, where `shortcut` is identity or a
//! projection (1×1 strided conv + BN) when the shape changes. The addition
//! and final ReLU stay in full precision; the convolutions inside both
//! paths carry the reduced-precision GEMMs.

use super::quant::QuantCtx;
use super::{Layer, Param, Sequential};
use crate::state::{StateError, StateMap};
use crate::tensor::Tensor;

pub struct Residual {
    pub main: Sequential,
    /// `None` = identity skip.
    pub shortcut: Option<Sequential>,
    mask: Vec<bool>,
    x_cache: Option<Tensor>,
}

impl Residual {
    pub fn new(main: Sequential, shortcut: Option<Sequential>) -> Self {
        Self {
            main,
            shortcut,
            mask: vec![],
            x_cache: None,
        }
    }
}

impl Layer for Residual {
    fn forward(&mut self, x: Tensor, ctx: &QuantCtx) -> Tensor {
        let skip = match &mut self.shortcut {
            Some(s) => s.forward(x.clone(), ctx),
            None => x.clone(),
        };
        if ctx.train && self.shortcut.is_none() {
            // Identity skip needs nothing cached; projection caches inside
            // its own layers.
        }
        let mut y = self.main.forward(x, ctx);
        assert_eq!(y.shape, skip.shape, "residual shape mismatch");
        y.add_assign(&skip);
        if ctx.train {
            self.mask = y.data.iter().map(|&v| v > 0.0).collect();
        }
        for v in &mut y.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        self.x_cache = None;
        y
    }

    fn backward(&mut self, mut dy: Tensor, ctx: &QuantCtx) -> Tensor {
        // Through the final ReLU.
        assert_eq!(dy.len(), self.mask.len(), "residual backward shape");
        for (v, &m) in dy.data.iter_mut().zip(&self.mask) {
            if !m {
                *v = 0.0;
            }
        }
        // The sum node fans the gradient into both branches.
        let mut dx = self.main.backward(dy.clone(), ctx);
        let dskip = match &mut self.shortcut {
            Some(s) => s.backward(dy, ctx),
            None => dy,
        };
        dx.add_assign(&dskip);
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        self.main.visit_params(f);
        if let Some(s) = &mut self.shortcut {
            s.visit_params(f);
        }
    }

    fn name(&self) -> String {
        "residual".into()
    }

    fn macs_per_example(&self) -> u64 {
        self.main.macs_per_example()
            + self
                .shortcut
                .as_ref()
                .map(|s| s.macs_per_example())
                .unwrap_or(0)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn save_extra_state(&mut self, prefix: &str, out: &mut StateMap) {
        self.main.save_extra_state(prefix, out);
        if let Some(s) = &mut self.shortcut {
            s.save_extra_state(prefix, out);
        }
    }

    fn load_extra_state(&mut self, prefix: &str, src: &StateMap) -> Result<(), StateError> {
        self.main.load_extra_state(prefix, src)?;
        if let Some(s) = &mut self.shortcut {
            s.load_extra_state(prefix, src)?;
        }
        Ok(())
    }

    fn invalidate_backward_state(&mut self) {
        // The block's own ReLU mask, plus both branches' layer caches.
        // (During an eval forward the branches already self-invalidate —
        // they run through `Sequential::forward` — but a direct call must
        // cover the whole subtree.)
        self.mask.clear();
        self.x_cache = None;
        self.main.invalidate_backward_state();
        if let Some(s) = &mut self.shortcut {
            s.invalidate_backward_state();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::act::Relu;
    use crate::nn::{PrecisionPolicy, QuantCtx};

    /// y = relu(relu(x)·1 + x) — a trivially checkable residual.
    #[test]
    fn identity_residual_forward_backward() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut r = Residual::new(Sequential::new(vec![Box::new(Relu::new())]), None);
        let x = Tensor::from_vec(&[1, 4], vec![1.0, -2.0, 3.0, -0.5]);
        let y = r.forward(x, &ctx);
        // main = relu(x) = [1,0,3,0]; sum = [2,-2,6,-0.5]; relu = [2,0,6,0]
        assert_eq!(y.data, vec![2.0, 0.0, 6.0, 0.0]);
        let dy = Tensor::from_vec(&[1, 4], vec![1.0; 4]);
        let dx = r.backward(dy, &ctx);
        // Positions 0,2 pass the outer relu; each contributes main-branch
        // relu grad (x>0 → 1) + skip grad (1).
        assert_eq!(dx.data, vec![2.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn gradient_check_residual() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let x = Tensor::from_vec(&[1, 4], vec![0.5, -0.3, 1.2, -2.0]);
        let dy = Tensor::from_vec(&[1, 4], vec![0.7, -0.2, 0.4, 1.0]);
        let mut r = Residual::new(Sequential::new(vec![Box::new(Relu::new())]), None);
        r.forward(x.clone(), &ctx);
        let dx = r.backward(dy.clone(), &ctx);

        let f = |x: &Tensor| -> f32 {
            let mut r = Residual::new(Sequential::new(vec![Box::new(Relu::new())]), None);
            let y = r.forward(x.clone(), &ctx);
            y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3;
        for i in 0..4 {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (f(&xp) - f(&xm)) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 1e-3,
                "i={i} num={num} got={}",
                dx.data[i]
            );
        }
    }
}
