//! `ModelSpec` — architecture as data.
//!
//! The model zoo used to be a closed enum (`ModelKind`) over six hand-wired
//! structs; every new scenario cost a recompile. A [`ModelSpec`] instead
//! *describes* an architecture — input shape, a list of layer items
//! (convolutions, FC layers, BN, residual stages, pooling) with optional
//! per-layer precision-position overrides — and compiles it onto the
//! existing `nn/` layers with spec-driven shape inference. Specs parse from
//! a compact text DSL (and print back canonically), so the CLI can train
//! arbitrary architectures from a string and checkpoints can embed the
//! architecture they were trained with.
//!
//! # DSL grammar (see `docs/model-spec.md` for the full reference)
//!
//! ```text
//! spec      := "mlp(" dims ")" | item ("-" item)*
//! item      := in | conv | maxpool | gap | flatten | relu | fc | res
//! in        := "in(" C "x" H "x" W ")" | "in(" D ")"        (first item only)
//! conv      := "conv" K "x" K "(" OC ["," arg]* ")" mods     args: sN pN bn bias nobias
//! maxpool   := "maxpool" K ["s" S]
//! fc        := "fc(" OUT ["," arg]* ")" mods                 args: bn bias nobias
//! res       := "res(" N "x" W ["," arg]* ")" mods            args: bE sS
//! mods      := ["#" name] ["@" ("first"|"middle"|"last")]
//! dims      := D "," hidden ("," hidden)* "," D              hidden: ["bn:"] W ["x" R]
//! ```
//!
//! Examples: `mlp(784,bn:256x3,10)`, `conv3x3(16)-res(2x32)-gap-fc(10)`.
//!
//! # Lowering rules
//!
//! - `conv` lowers to [`Conv2d`] (+ [`BatchNorm`] when `bn`) + [`Relu`];
//!   bias defaults to `!bn`, padding to `k/2` (same-padding), stride to 1.
//! - `fc` lowers to [`Linear`] (+ `BatchNorm` 1-D when `bn`); a `Flatten`
//!   is inserted automatically when the incoming shape is an image.
//! - `res(NxW)` lowers to `N` basic residual blocks of width `W` (`b E`
//!   selects bottleneck blocks with expansion `E`). The first block of a
//!   stage strides 2 unless it is the first `res` item of the spec
//!   (overridable with `sS`) — the canonical ResNet stage pattern.
//! - `mlp(d0, …, dn)` is sugar for `in(d0)` + hidden `fc(W[,bn])-relu`
//!   pairs + final `fc(dn)`.
//!
//! # The stable walk: names and precision positions
//!
//! Layer names feed both checkpoint keys (`model.<name>.w`) and the
//! stochastic-rounding seeds (`QuantCtx::gemm_seed` hashes the name), so
//! they are assigned by a deterministic walk over the items:
//!
//! - conv items: `conv1`, `conv2`, … (1-based, conv items only);
//! - fc items: `fc` when the spec has exactly one fc item, else `fc1…fcN`;
//! - res stages: `s0`, `s1`, … with blocks `s{i}b{j}` (their inner layers
//!   are named by the shared block builders: `.c1`, `.bn1`, `.proj`, …);
//! - an explicit `#name` overrides the auto name (this is how the presets
//!   pin historical names like `stem` and `fc6`).
//!
//! Precision positions generalize the paper's §4.1 first/last-layer rules:
//! by default the first top-level GEMM item is [`LayerPos::First`], the
//! last is [`LayerPos::Last`] (a single GEMM layer is `Last` — Softmax
//! fidelity wins), everything else — including all res-internal convs — is
//! `Middle`. `@first/@middle/@last` overrides any item, which turns the
//! Table 2/3 first/last-layer ablations into one-line spec edits.
//!
//! # Presets
//!
//! The paper's six benchmark networks are named preset specs
//! ([`ModelSpec::preset`]). Contract (enforced by `rust/tests/
//! spec_bridge.rs`): spec-built presets are element-wise bit-identical to
//! the historical hand-built models — same construction-RNG draw order,
//! same layer names (hence same SR streams and `StateDict` keys) — so
//! checkpoints written before this API existed keep loading.

use super::act::Relu;
use super::conv::Conv2d;
use super::linear::Linear;
use super::models::{basic_block, bottleneck_block, InputKind};
use super::norm::BatchNorm;
use super::pool::{GlobalAvgPool, MaxPool2d};
use super::quant::LayerPos;
use super::{Flatten, Layer, Sequential};
use crate::numerics::Xoshiro256;
use crate::tensor::Conv2dGeom;
use std::fmt;

/// A malformed or inconsistent model spec (parse error, shape-inference
/// failure, name collision, …).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecError(pub String);

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "model spec: {}", self.0)
    }
}

impl std::error::Error for SpecError {}

fn err<T>(msg: impl Into<String>) -> Result<T, SpecError> {
    Err(SpecError(msg.into()))
}

/// One declarative item of a [`ModelSpec`] (one DSL token).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ItemSpec {
    /// k×k convolution (+ optional BN) + ReLU.
    Conv {
        k: usize,
        out_c: usize,
        stride: usize,
        pad: usize,
        bias: bool,
        bn: bool,
        name: Option<String>,
        pos: Option<LayerPos>,
    },
    /// k×k max pooling.
    MaxPool { k: usize, stride: usize },
    /// Global average pooling (NCHW → [N, C]).
    Gap,
    /// Explicit NCHW → [N, C·H·W] reshape (also inserted automatically
    /// before an `fc` that receives an image).
    Flatten,
    /// Standalone ReLU (fc items do not add one implicitly).
    Relu,
    /// Fully-connected layer (+ optional 1-D BN).
    Fc {
        out: usize,
        bias: bool,
        bn: bool,
        name: Option<String>,
        pos: Option<LayerPos>,
    },
    /// A residual stage: `blocks` basic (or bottleneck, when
    /// `expand.is_some()`) blocks of `width` channels.
    Res {
        blocks: usize,
        width: usize,
        expand: Option<usize>,
        stride: Option<usize>,
        name: Option<String>,
    },
}

/// A declarative, parseable model description. Construct via
/// [`ModelSpec::resolve`] (preset name or DSL string), [`ModelSpec::parse`]
/// (DSL only) or [`SpecBuilder`]; every constructor validates shapes, names
/// and positions, so [`ModelSpec::build`] cannot fail.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Set when this spec was resolved from a named preset; pins the
    /// engine/checkpoint identity to the historical short id.
    preset: Option<&'static str>,
    input: InputKind,
    items: Vec<ItemSpec>,
}

/// Architecture equality: two specs are equal iff they describe the same
/// network — the preset tag is identity metadata, not architecture.
impl PartialEq for ModelSpec {
    fn eq(&self, other: &Self) -> bool {
        self.input == other.input && self.items == other.items
    }
}

/// The validated lowering plan: one entry per concrete layer-group, with
/// resolved names, positions and shapes. Produced by the stable walk.
struct Plan {
    steps: Vec<PlanStep>,
    classes: usize,
}

enum PlanStep {
    Conv {
        name: String,
        geom: Conv2dGeom,
        out_c: usize,
        bias: bool,
        bn: bool,
        pos: LayerPos,
    },
    MaxPool {
        k: usize,
        stride: usize,
    },
    Gap,
    Flatten,
    Relu,
    Fc {
        name: String,
        in_dim: usize,
        out: usize,
        bias: bool,
        bn: bool,
        pos: LayerPos,
        flatten_first: bool,
    },
    Block {
        name: String,
        in_c: usize,
        hw: usize,
        width: usize,
        expand: Option<usize>,
        stride: usize,
    },
}

/// Shape state threaded through the inference walk.
#[derive(Clone, Copy, Debug)]
enum Shape {
    Img { c: usize, h: usize, w: usize },
    Flat { d: usize },
}

/// One concrete layer slot of the `Sequential` that [`ModelSpec::build`]
/// produces — the public lowering surface consumed by `crate::program`.
///
/// [`ModelSpec::lower_units`] emits exactly one unit per built layer, in
/// build order, so unit index `i` describes `Sequential::layers[i]` (and,
/// inside a [`LoweredUnit::Residual`], the `main`/`shortcut` vectors
/// address the nested `Sequential`s the same way). All shapes are fully
/// resolved at lowering — consumers never re-run shape inference.
#[derive(Clone, Debug)]
pub enum LoweredUnit {
    Conv {
        name: String,
        geom: Conv2dGeom,
        out_c: usize,
        bias: bool,
        pos: LayerPos,
    },
    BatchNorm {
        name: String,
        features: usize,
        per_example: usize,
    },
    Relu {
        per_example: usize,
    },
    MaxPool {
        k: usize,
        stride: usize,
        c: usize,
        in_h: usize,
        in_w: usize,
    },
    Gap {
        c: usize,
        in_h: usize,
        in_w: usize,
    },
    Flatten {
        per_example: usize,
    },
    Linear {
        name: String,
        in_dim: usize,
        out: usize,
        bias: bool,
        pos: LayerPos,
    },
    Residual {
        name: String,
        main: Vec<LoweredUnit>,
        shortcut: Vec<LoweredUnit>,
    },
}

/// Mirror of `models::{basic_block, bottleneck_block}` geometry for the
/// lowering surface. Must stay in lock-step with those builders — the
/// `lower_units_align_with_build` test guards the per-layer counts and
/// `rust/tests/program_equivalence.rs` guards the behavior end to end.
fn lower_block(
    name: &str,
    in_c: usize,
    hw: usize,
    width: usize,
    expand: Option<usize>,
    stride: usize,
) -> LoweredUnit {
    let out_hw = (hw + 2 - 3) / stride + 1;
    let conv = |n: &str, geom: Conv2dGeom, out_c: usize| LoweredUnit::Conv {
        name: format!("{name}.{n}"),
        geom,
        out_c,
        bias: false,
        pos: LayerPos::Middle,
    };
    let bn = |n: &str, c: usize, hw: usize| LoweredUnit::BatchNorm {
        name: format!("{name}.{n}"),
        features: c,
        per_example: c * hw * hw,
    };
    let (out_c, main) = match expand {
        None => {
            let g1 = Conv2dGeom { in_c, in_h: hw, in_w: hw, k: 3, stride, pad: 1 };
            let g2 = Conv2dGeom {
                in_c: width,
                in_h: out_hw,
                in_w: out_hw,
                k: 3,
                stride: 1,
                pad: 1,
            };
            (
                width,
                vec![
                    conv("c1", g1, width),
                    bn("bn1", width, out_hw),
                    LoweredUnit::Relu { per_example: width * out_hw * out_hw },
                    conv("c2", g2, width),
                    bn("bn2", width, out_hw),
                ],
            )
        }
        Some(e) => {
            let out_c = width * e;
            let g1 = Conv2dGeom { in_c, in_h: hw, in_w: hw, k: 1, stride: 1, pad: 0 };
            let g2 = Conv2dGeom { in_c: width, in_h: hw, in_w: hw, k: 3, stride, pad: 1 };
            let g3 = Conv2dGeom {
                in_c: width,
                in_h: out_hw,
                in_w: out_hw,
                k: 1,
                stride: 1,
                pad: 0,
            };
            (
                out_c,
                vec![
                    conv("c1", g1, width),
                    bn("bn1", width, hw),
                    LoweredUnit::Relu { per_example: width * hw * hw },
                    conv("c2", g2, width),
                    bn("bn2", width, out_hw),
                    LoweredUnit::Relu { per_example: width * out_hw * out_hw },
                    conv("c3", g3, out_c),
                    bn("bn3", out_c, out_hw),
                ],
            )
        }
    };
    let shortcut = if stride != 1 || in_c != out_c {
        let gp = Conv2dGeom { in_c, in_h: hw, in_w: hw, k: 1, stride, pad: 0 };
        vec![conv("proj", gp, out_c), bn("bnp", out_c, out_hw)]
    } else {
        Vec::new()
    };
    LoweredUnit::Residual {
        name: name.to_string(),
        main,
        shortcut,
    }
}

/// The six paper networks as named preset specs (Appendix A, scaled per
/// DESIGN.md §7). The DSL strings pin the historical layer names where the
/// stable walk would pick different ones (`#stem`, `#fc6`…).
pub const PRESETS: [(&str, &str); 6] = [
    (
        "cifar_cnn",
        "conv5x5(16)-maxpool2-conv5x5(32)-maxpool2-conv5x5(32)-maxpool2-fc(10)#fc",
    ),
    (
        "cifar_resnet",
        "conv3x3(16,bn)#stem-res(2x16)-res(2x32)-res(2x64)-gap-fc(10)#fc",
    ),
    ("bn50_dnn", "mlp(440,256x5,30)"),
    (
        "alexnet",
        "conv3x3(24)-maxpool2-conv3x3(48)-maxpool2-conv3x3(64)-conv3x3(64)-conv3x3(48)-maxpool2-\
         fc(256)#fc6-relu-fc(256)#fc7-relu-fc(10)#fc8",
    ),
    (
        "resnet18",
        "conv3x3(16,bn)#stem-res(2x16)-res(2x32)-res(2x64)-res(2x128)-gap-fc(10)#fc",
    ),
    (
        "resnet50",
        "conv3x3(16,bn)#stem-res(2x16,b4)-res(2x32,b4)-res(2x64,b4)-res(2x128,b4)-gap-fc(10)#fc",
    ),
];

impl ModelSpec {
    /// The preset ids, in the paper's Table 1 order.
    pub const PRESET_IDS: [&'static str; 6] = [
        "cifar_cnn",
        "cifar_resnet",
        "bn50_dnn",
        "alexnet",
        "resnet18",
        "resnet50",
    ];

    /// Look up a named preset.
    pub fn preset(id: &str) -> Option<ModelSpec> {
        PRESETS.iter().find(|(p, _)| *p == id).map(|&(p, dsl)| {
            let mut spec = Self::parse(dsl).expect("preset spec must parse");
            spec.preset = Some(p);
            spec
        })
    }

    pub fn cifar_cnn() -> ModelSpec {
        Self::preset("cifar_cnn").unwrap()
    }

    pub fn cifar_resnet() -> ModelSpec {
        Self::preset("cifar_resnet").unwrap()
    }

    pub fn bn50_dnn() -> ModelSpec {
        Self::preset("bn50_dnn").unwrap()
    }

    pub fn alexnet() -> ModelSpec {
        Self::preset("alexnet").unwrap()
    }

    pub fn resnet18() -> ModelSpec {
        Self::preset("resnet18").unwrap()
    }

    pub fn resnet50() -> ModelSpec {
        Self::preset("resnet50").unwrap()
    }

    /// All six presets, in Table 1 order.
    pub fn all_presets() -> Vec<ModelSpec> {
        Self::PRESET_IDS
            .iter()
            .map(|id| Self::preset(id).unwrap())
            .collect()
    }

    /// The CLI/checkpoint entry point: a preset name or a DSL string.
    pub fn resolve(s: &str) -> Result<ModelSpec, SpecError> {
        let s = s.trim();
        if let Some(spec) = Self::preset(s) {
            return Ok(spec);
        }
        Self::parse(s).map_err(|e| {
            SpecError(format!(
                "{} (not a preset either; presets: {})",
                e.0,
                Self::PRESET_IDS.join(", ")
            ))
        })
    }

    /// Parse a DSL string (`mlp(…)` sugar or the dash form).
    pub fn parse(s: &str) -> Result<ModelSpec, SpecError> {
        let s = s.trim();
        if s.is_empty() {
            return err("empty spec");
        }
        let (input, items) = if let Some(inner) = strip_call(s, "mlp") {
            parse_mlp(inner)?
        } else {
            parse_dash(s)?
        };
        let spec = ModelSpec {
            preset: None,
            input,
            items,
        };
        spec.plan()?; // validate shapes, names, positions
        Ok(spec)
    }

    /// Expand a spec *template* — a DSL (or preset-name) string with
    /// `{a,b,c}` placeholder axes — into the list of concrete spec
    /// strings, in deterministic order.
    ///
    /// Placeholders are plain textual alternations, so one syntax covers
    /// width axes (`conv3x3({8,16})`), depth axes (`res({1,2}x32)`,
    /// `mlp(440,bn:256x{3,5},30)`) *and* precision-position axes
    /// (`fc(10)@{middle,last}`). Ordering contract (the sweep grid and
    /// cell ids depend on it): the **leftmost placeholder varies
    /// slowest**; a template without placeholders expands to itself.
    /// Nesting and unmatched braces are errors, as is a grid wider than
    /// [`MAX_TEMPLATE_EXPANSIONS`]. The expansions are *not* parsed here —
    /// callers validate each with [`ModelSpec::resolve`] so error messages
    /// can point at the offending concrete spec.
    pub fn expand_template(template: &str) -> Result<Vec<String>, SpecError> {
        let mut out = Vec::new();
        expand_template_into(template, &mut out)?;
        Ok(out)
    }

    /// Re-derive this spec with the precision position of its **last**
    /// conv/fc item overridden — the sweep's `pos` axis (the §4.1/Table 3
    /// last-layer lever applied to arbitrary architectures). The result is
    /// re-validated and loses any preset tag (its identity is the
    /// canonical DSL, which records the override).
    pub fn with_pos_override(&self, pos: LayerPos) -> Result<ModelSpec, SpecError> {
        let mut items = self.items.clone();
        let slot = items.iter_mut().rev().find_map(|i| match i {
            ItemSpec::Conv { pos, .. } | ItemSpec::Fc { pos, .. } => Some(pos),
            _ => None,
        });
        match slot {
            Some(p) => *p = Some(pos),
            None => return err("spec has no conv/fc item to position-override"),
        }
        let spec = ModelSpec {
            preset: None,
            input: self.input,
            items,
        };
        spec.plan()?;
        Ok(spec)
    }

    /// The preset id this spec was resolved from, if any.
    pub fn preset_id(&self) -> Option<&'static str> {
        self.preset
    }

    /// Stable identity string: the preset id when this is a preset
    /// (keeping historical engine tags / checkpoint compatibility), the
    /// canonical DSL otherwise.
    pub fn id(&self) -> String {
        match self.preset {
            Some(p) => p.to_string(),
            None => self.canonical(),
        }
    }

    /// Canonical dash-form DSL (round-trips through [`ModelSpec::parse`]).
    pub fn canonical(&self) -> String {
        let mut out = match self.input {
            InputKind::Image { c, h, w } => format!("in({c}x{h}x{w})"),
            InputKind::Vector { dim } => format!("in({dim})"),
        };
        for item in &self.items {
            out.push('-');
            out.push_str(&print_item(item));
        }
        out
    }

    /// A filesystem-safe stem for default checkpoint paths.
    pub fn file_stem(&self) -> String {
        let id = self.id();
        let mut stem: String = id
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect();
        stem.truncate(48);
        stem
    }

    /// What the model consumes (drives the synthetic data generators).
    pub fn input(&self) -> InputKind {
        self.input
    }

    /// Output width of the final layer = class count of the workload.
    pub fn classes(&self) -> usize {
        self.validated_plan().classes
    }

    pub fn items(&self) -> &[ItemSpec] {
        &self.items
    }

    fn validated_plan(&self) -> Plan {
        self.plan()
            .expect("ModelSpec invariant: validated at construction")
    }

    /// Compile the spec into the layer stack with deterministic
    /// initialization — the replacement for the per-model hand wiring.
    pub fn build(&self, seed: u64) -> Sequential {
        let plan = self.validated_plan();
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut layers: Vec<Box<dyn Layer>> = Vec::new();
        for step in &plan.steps {
            match step {
                PlanStep::Conv {
                    name,
                    geom,
                    out_c,
                    bias,
                    bn,
                    pos,
                } => {
                    layers.push(Box::new(Conv2d::new(name, *geom, *out_c, *pos, *bias, &mut rng)));
                    if *bn {
                        layers.push(Box::new(BatchNorm::new_2d(&format!("{name}.bn"), *out_c)));
                    }
                    layers.push(Box::new(Relu::new()));
                }
                PlanStep::MaxPool { k, stride } => {
                    layers.push(Box::new(MaxPool2d::new(*k, *stride)));
                }
                PlanStep::Gap => layers.push(Box::new(GlobalAvgPool::new())),
                PlanStep::Flatten => layers.push(Box::new(Flatten::new())),
                PlanStep::Relu => layers.push(Box::new(Relu::new())),
                PlanStep::Fc {
                    name,
                    in_dim,
                    out,
                    bias,
                    bn,
                    pos,
                    flatten_first,
                } => {
                    if *flatten_first {
                        layers.push(Box::new(Flatten::new()));
                    }
                    let mut l = Linear::new(name, *in_dim, *out, *pos, &mut rng);
                    if !bias {
                        l = l.no_bias();
                    }
                    layers.push(Box::new(l));
                    if *bn {
                        layers.push(Box::new(BatchNorm::new_1d(&format!("{name}.bn"), *out)));
                    }
                }
                PlanStep::Block {
                    name,
                    in_c,
                    hw,
                    width,
                    expand,
                    stride,
                } => match expand {
                    Some(e) => {
                        let (block, _, _) =
                            bottleneck_block(name, *in_c, *hw, *width, *e, *stride, &mut rng);
                        layers.push(Box::new(block));
                    }
                    None => {
                        let (block, _) = basic_block(name, *in_c, *hw, *width, *stride, &mut rng);
                        layers.push(Box::new(block));
                    }
                },
            }
        }
        Sequential::new(layers)
    }

    /// Flatten the validated plan into per-layer lowering records — one
    /// [`LoweredUnit`] per layer of [`ModelSpec::build`]'s `Sequential`,
    /// in build order. `crate::program` compiles these into a step
    /// program; the positional alignment with `build` is what lets
    /// program exec steps address layers by index.
    pub fn lower_units(&self) -> Vec<LoweredUnit> {
        let plan = self.validated_plan();
        let mut shape = match self.input {
            InputKind::Image { c, h, w } => Shape::Img { c, h, w },
            InputKind::Vector { dim } => Shape::Flat { d: dim },
        };
        let per_example = |s: &Shape| match *s {
            Shape::Img { c, h, w } => c * h * w,
            Shape::Flat { d } => d,
        };
        let mut units = Vec::new();
        for step in &plan.steps {
            match step {
                PlanStep::Conv {
                    name,
                    geom,
                    out_c,
                    bias,
                    bn,
                    pos,
                } => {
                    units.push(LoweredUnit::Conv {
                        name: name.clone(),
                        geom: *geom,
                        out_c: *out_c,
                        bias: *bias,
                        pos: *pos,
                    });
                    shape = Shape::Img {
                        c: *out_c,
                        h: geom.out_h(),
                        w: geom.out_w(),
                    };
                    if *bn {
                        units.push(LoweredUnit::BatchNorm {
                            name: format!("{name}.bn"),
                            features: *out_c,
                            per_example: per_example(&shape),
                        });
                    }
                    units.push(LoweredUnit::Relu {
                        per_example: per_example(&shape),
                    });
                }
                PlanStep::MaxPool { k, stride } => {
                    let Shape::Img { c, h, w } = shape else {
                        unreachable!("validated plan: maxpool over image")
                    };
                    units.push(LoweredUnit::MaxPool {
                        k: *k,
                        stride: *stride,
                        c,
                        in_h: h,
                        in_w: w,
                    });
                    shape = Shape::Img {
                        c,
                        h: (h - k) / stride + 1,
                        w: (w - k) / stride + 1,
                    };
                }
                PlanStep::Gap => {
                    let Shape::Img { c, h, w } = shape else {
                        unreachable!("validated plan: gap over image")
                    };
                    units.push(LoweredUnit::Gap { c, in_h: h, in_w: w });
                    shape = Shape::Flat { d: c };
                }
                PlanStep::Flatten => {
                    units.push(LoweredUnit::Flatten {
                        per_example: per_example(&shape),
                    });
                    shape = Shape::Flat { d: per_example(&shape) };
                }
                PlanStep::Relu => units.push(LoweredUnit::Relu {
                    per_example: per_example(&shape),
                }),
                PlanStep::Fc {
                    name,
                    in_dim,
                    out,
                    bias,
                    bn,
                    pos,
                    flatten_first,
                } => {
                    if *flatten_first {
                        units.push(LoweredUnit::Flatten { per_example: *in_dim });
                    }
                    units.push(LoweredUnit::Linear {
                        name: name.clone(),
                        in_dim: *in_dim,
                        out: *out,
                        bias: *bias,
                        pos: *pos,
                    });
                    if *bn {
                        units.push(LoweredUnit::BatchNorm {
                            name: format!("{name}.bn"),
                            features: *out,
                            per_example: *out,
                        });
                    }
                    shape = Shape::Flat { d: *out };
                }
                PlanStep::Block {
                    name,
                    in_c,
                    hw,
                    width,
                    expand,
                    stride,
                } => {
                    units.push(lower_block(name, *in_c, *hw, *width, *expand, *stride));
                    let out_c = width * expand.unwrap_or(1);
                    let out_hw = (hw + 2 - 3) / stride + 1;
                    shape = Shape::Img {
                        c: out_c,
                        h: out_hw,
                        w: out_hw,
                    };
                }
            }
        }
        units
    }

    /// The stable walk: shape inference + name/position assignment +
    /// validation, in one deterministic pass.
    fn plan(&self) -> Result<Plan, SpecError> {
        if self.items.is_empty() {
            return err("spec has no layers");
        }
        match self.input {
            InputKind::Image { c, h, w } => {
                check_dims(&[(c, "input channels"), (h, "input height"), (w, "input width")])?
            }
            InputKind::Vector { dim } => check_dims(&[(dim, "input dim")])?,
        }
        // Pass 1: counts and first/last top-level GEMM items.
        let fc_total = self
            .items
            .iter()
            .filter(|i| matches!(i, ItemSpec::Fc { .. }))
            .count();
        let gemm_idx: Vec<usize> = self
            .items
            .iter()
            .enumerate()
            .filter(|(_, i)| matches!(i, ItemSpec::Conv { .. } | ItemSpec::Fc { .. }))
            .map(|(n, _)| n)
            .collect();
        let auto_pos = |idx: usize| -> LayerPos {
            // A single GEMM layer feeds the Softmax: Last wins (§4.1 —
            // Softmax fidelity dominates, and Last already implies wide
            // operands under the paper scheme).
            if Some(&idx) == gemm_idx.last() {
                LayerPos::Last
            } else if Some(&idx) == gemm_idx.first() {
                LayerPos::First
            } else {
                LayerPos::Middle
            }
        };

        // Pass 2: the walk.
        let mut shape = match self.input {
            InputKind::Image { c, h, w } => Shape::Img { c, h, w },
            InputKind::Vector { dim } => Shape::Flat { d: dim },
        };
        let mut steps = Vec::with_capacity(self.items.len());
        let mut names: Vec<String> = Vec::new();
        let (mut conv_n, mut fc_n, mut res_n) = (0usize, 0usize, 0usize);
        for (idx, item) in self.items.iter().enumerate() {
            match item {
                ItemSpec::Conv {
                    k,
                    out_c,
                    stride,
                    pad,
                    bias,
                    bn,
                    name,
                    pos,
                } => {
                    conv_n += 1;
                    let Shape::Img { c, h, w } = shape else {
                        return err(format!("conv #{conv_n} needs an image input, got a vector"));
                    };
                    check_dims(&[(*k, "kernel"), (*out_c, "channels"), (*stride, "stride")])?;
                    let (oh, ow) = conv_out(h, w, *k, *stride, *pad)
                        .ok_or_else(|| SpecError(format!(
                            "conv #{conv_n}: {k}x{k} kernel (pad {pad}) exceeds {h}x{w} input"
                        )))?;
                    let name = resolve_name(name, format!("conv{conv_n}"))?;
                    names.push(name.clone());
                    steps.push(PlanStep::Conv {
                        name,
                        geom: Conv2dGeom {
                            in_c: c,
                            in_h: h,
                            in_w: w,
                            k: *k,
                            stride: *stride,
                            pad: *pad,
                        },
                        out_c: *out_c,
                        bias: *bias,
                        bn: *bn,
                        pos: pos.unwrap_or_else(|| auto_pos(idx)),
                    });
                    shape = Shape::Img {
                        c: *out_c,
                        h: oh,
                        w: ow,
                    };
                }
                ItemSpec::MaxPool { k, stride } => {
                    let Shape::Img { c, h, w } = shape else {
                        return err("maxpool needs an image input, got a vector");
                    };
                    check_dims(&[(*k, "kernel"), (*stride, "stride")])?;
                    if *k > h || *k > w {
                        return err(format!("maxpool{k} exceeds {h}x{w} input"));
                    }
                    steps.push(PlanStep::MaxPool {
                        k: *k,
                        stride: *stride,
                    });
                    shape = Shape::Img {
                        c,
                        h: (h - k) / stride + 1,
                        w: (w - k) / stride + 1,
                    };
                }
                ItemSpec::Gap => {
                    let Shape::Img { c, .. } = shape else {
                        return err("gap needs an image input, got a vector");
                    };
                    steps.push(PlanStep::Gap);
                    shape = Shape::Flat { d: c };
                }
                ItemSpec::Flatten => {
                    let Shape::Img { c, h, w } = shape else {
                        return err("flatten needs an image input, got a vector");
                    };
                    steps.push(PlanStep::Flatten);
                    shape = Shape::Flat { d: c * h * w };
                }
                ItemSpec::Relu => steps.push(PlanStep::Relu),
                ItemSpec::Fc {
                    out,
                    bias,
                    bn,
                    name,
                    pos,
                } => {
                    fc_n += 1;
                    check_dims(&[(*out, "width")])?;
                    let (in_dim, flatten_first) = match shape {
                        Shape::Flat { d } => (d, false),
                        Shape::Img { c, h, w } => (c * h * w, true),
                    };
                    let auto = if fc_total == 1 {
                        "fc".to_string()
                    } else {
                        format!("fc{fc_n}")
                    };
                    let name = resolve_name(name, auto)?;
                    names.push(name.clone());
                    steps.push(PlanStep::Fc {
                        name,
                        in_dim,
                        out: *out,
                        bias: *bias,
                        bn: *bn,
                        pos: pos.unwrap_or_else(|| auto_pos(idx)),
                        flatten_first,
                    });
                    shape = Shape::Flat { d: *out };
                }
                ItemSpec::Res {
                    blocks,
                    width,
                    expand,
                    stride,
                    name,
                } => {
                    let stage = res_n;
                    res_n += 1;
                    check_dims(&[(*blocks, "block count"), (*width, "width")])?;
                    if let Some(e) = expand {
                        check_dims(&[(*e, "expansion")])?;
                    }
                    if let Some(s) = stride {
                        check_dims(&[(*s, "stride")])?;
                    }
                    let stage_name = resolve_name(name, format!("s{stage}"))?;
                    for b in 0..*blocks {
                        let Shape::Img { c, h, w } = shape else {
                            return err(format!(
                                "res stage {stage_name} needs an image input, got a vector"
                            ));
                        };
                        if h != w {
                            return err(format!(
                                "res stage {stage_name} needs a square input, got {h}x{w}"
                            ));
                        }
                        // The canonical stage pattern: the first block of
                        // every stage but the spec's first strides 2.
                        let s = if b == 0 {
                            stride.unwrap_or(if stage > 0 { 2 } else { 1 })
                        } else {
                            1
                        };
                        let out_hw = (h + 2).checked_sub(3).map(|d| d / s + 1).ok_or_else(|| {
                            SpecError(format!("res stage {stage_name}: input {h}x{w} too small"))
                        })?;
                        let out_c = width * expand.unwrap_or(1);
                        names.push(format!("{stage_name}b{b}"));
                        steps.push(PlanStep::Block {
                            name: format!("{stage_name}b{b}"),
                            in_c: c,
                            hw: h,
                            width: *width,
                            expand: *expand,
                            stride: s,
                        });
                        shape = Shape::Img {
                            c: out_c,
                            h: out_hw,
                            w: out_hw,
                        };
                    }
                }
            }
        }
        // Distinct layer-name prefixes (conv/fc names and every residual
        // block's `s{i}b{j}`). Exact duplicates would alias SR streams and
        // checkpoint keys outright; a name that extends another with a `.`
        // segment (e.g. an explicit `#s0b0.c1` next to a res stage `s0`)
        // could collide with block-internal names (`.c1`, `.bn1`, `.proj`,
        // …), so dotted-prefix overlaps are rejected too.
        for (i, a) in names.iter().enumerate() {
            for b in &names[i + 1..] {
                if a == b {
                    return err(format!("duplicate layer name {a:?}"));
                }
                let (a_dot, b_dot) = (format!("{a}."), format!("{b}."));
                if a.starts_with(b_dot.as_str()) || b.starts_with(a_dot.as_str()) {
                    return err(format!(
                        "layer names {a:?} and {b:?} overlap (one is a dotted prefix of the \
                         other), which would alias checkpoint keys"
                    ));
                }
            }
        }
        let Shape::Flat { d } = shape else {
            return err("model must end with a 2-D output (finish with fc or gap)");
        };
        Ok(Plan { steps, classes: d })
    }
}

impl fmt::Display for ModelSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.canonical())
    }
}

fn check_dims(dims: &[(usize, &str)]) -> Result<(), SpecError> {
    for (v, what) in dims {
        if *v == 0 {
            return err(format!("{what} must be ≥ 1"));
        }
    }
    Ok(())
}

/// Output spatial dims of a conv, or `None` when the kernel exceeds the
/// padded input.
fn conv_out(h: usize, w: usize, k: usize, stride: usize, pad: usize) -> Option<(usize, usize)> {
    let oh = (h + 2 * pad).checked_sub(k)? / stride + 1;
    let ow = (w + 2 * pad).checked_sub(k)? / stride + 1;
    Some((oh, ow))
}

fn resolve_name(explicit: &Option<String>, auto: String) -> Result<String, SpecError> {
    match explicit {
        None => Ok(auto),
        Some(n) => {
            if n.is_empty() || !n.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.')
            {
                return err(format!(
                    "layer name {n:?} must be non-empty [A-Za-z0-9_.]"
                ));
            }
            Ok(n.clone())
        }
    }
}

// ---- template expansion ----------------------------------------------------

/// Widest grid a single template may expand to; a typo like
/// `{1,2,3,4,5,6,7,8}` repeated across many axes should fail loudly, not
/// allocate a million strings.
pub const MAX_TEMPLATE_EXPANSIONS: usize = 4096;

/// Recursive worker behind [`ModelSpec::expand_template`]: substitute each
/// alternative of the leftmost `{…}` and recurse on the result, so the
/// leftmost axis varies slowest.
fn expand_template_into(s: &str, out: &mut Vec<String>) -> Result<(), SpecError> {
    let Some(open) = s.find('{') else {
        if s.contains('}') {
            return err(format!("unmatched '}}' in template {s:?}"));
        }
        if out.len() >= MAX_TEMPLATE_EXPANSIONS {
            return err(format!(
                "template expands to more than {MAX_TEMPLATE_EXPANSIONS} specs"
            ));
        }
        out.push(s.to_string());
        return Ok(());
    };
    if s[..open].contains('}') {
        return err(format!("unmatched '}}' in template {s:?}"));
    }
    let rest = &s[open + 1..];
    let close = rest
        .find('}')
        .ok_or_else(|| SpecError(format!("unmatched '{{' in template {s:?}")))?;
    let inner = &rest[..close];
    if inner.contains('{') {
        return err(format!("nested '{{' in template {s:?}"));
    }
    if inner.is_empty() {
        return err(format!("empty placeholder {{}} in template {s:?}"));
    }
    for alt in inner.split(',') {
        let alt = alt.trim();
        if alt.is_empty() {
            return err(format!("empty alternative in {{{inner}}} of template {s:?}"));
        }
        let expanded = format!("{}{}{}", &s[..open], alt, &rest[close + 1..]);
        expand_template_into(&expanded, out)?;
    }
    Ok(())
}

// ---- printing --------------------------------------------------------------

fn print_mods(name: &Option<String>, pos: &Option<LayerPos>) -> String {
    let mut out = String::new();
    if let Some(n) = name {
        out.push('#');
        out.push_str(n);
    }
    if let Some(p) = pos {
        out.push('@');
        out.push_str(pos_id(*p));
    }
    out
}

fn pos_id(pos: LayerPos) -> &'static str {
    match pos {
        LayerPos::First => "first",
        LayerPos::Middle => "middle",
        LayerPos::Last => "last",
    }
}

fn print_item(item: &ItemSpec) -> String {
    match item {
        ItemSpec::Conv {
            k,
            out_c,
            stride,
            pad,
            bias,
            bn,
            name,
            pos,
        } => {
            let mut args = format!("{out_c}");
            if *stride != 1 {
                args.push_str(&format!(",s{stride}"));
            }
            if *pad != k / 2 {
                args.push_str(&format!(",p{pad}"));
            }
            if *bn {
                args.push_str(",bn");
            }
            // bias defaults to !bn; print only the deviation.
            if *bias == *bn {
                args.push_str(if *bias { ",bias" } else { ",nobias" });
            }
            format!("conv{k}x{k}({args}){}", print_mods(name, pos))
        }
        ItemSpec::MaxPool { k, stride } => {
            if stride == k {
                format!("maxpool{k}")
            } else {
                format!("maxpool{k}s{stride}")
            }
        }
        ItemSpec::Gap => "gap".into(),
        ItemSpec::Flatten => "flatten".into(),
        ItemSpec::Relu => "relu".into(),
        ItemSpec::Fc {
            out,
            bias,
            bn,
            name,
            pos,
        } => {
            let mut args = format!("{out}");
            if *bn {
                args.push_str(",bn");
            }
            if !bias {
                args.push_str(",nobias");
            }
            format!("fc({args}){}", print_mods(name, pos))
        }
        ItemSpec::Res {
            blocks,
            width,
            expand,
            stride,
            name,
        } => {
            let mut args = format!("{blocks}x{width}");
            if let Some(e) = expand {
                args.push_str(&format!(",b{e}"));
            }
            if let Some(s) = stride {
                args.push_str(&format!(",s{s}"));
            }
            format!("res({args}){}", print_mods(name, &None))
        }
    }
}

// ---- parsing ---------------------------------------------------------------

/// `"head(inner)"` → `Some(inner)` (whole-string match).
fn strip_call<'a>(s: &'a str, head: &str) -> Option<&'a str> {
    s.strip_prefix(head)?
        .strip_prefix('(')?
        .strip_suffix(')')
}

fn num(s: &str, what: &str) -> Result<usize, SpecError> {
    s.parse()
        .map_err(|_| SpecError(format!("cannot parse {what} from {s:?}")))
}

/// Split `"...#name@pos"` modifier suffixes off a token body.
fn split_mods(tok: &str) -> Result<(&str, Option<String>, Option<LayerPos>), SpecError> {
    let (rest, pos) = match tok.rsplit_once('@') {
        Some((rest, p)) => {
            let pos = match p {
                "first" => LayerPos::First,
                "middle" => LayerPos::Middle,
                "last" => LayerPos::Last,
                other => return err(format!("unknown position {other:?} (first|middle|last)")),
            };
            (rest, Some(pos))
        }
        None => (tok, None),
    };
    let (core, name) = match rest.rsplit_once('#') {
        Some((core, n)) => (core, Some(n.to_string())),
        None => (rest, None),
    };
    if let Some(n) = &name {
        resolve_name(&Some(n.clone()), String::new())?;
    }
    Ok((core, name, pos))
}

fn parse_input(inner: &str) -> Result<InputKind, SpecError> {
    let dims: Vec<&str> = inner.split('x').collect();
    match dims.as_slice() {
        [d] => Ok(InputKind::Vector {
            dim: num(d, "input dim")?,
        }),
        [c, h, w] => Ok(InputKind::Image {
            c: num(c, "input channels")?,
            h: num(h, "input height")?,
            w: num(w, "input width")?,
        }),
        _ => err(format!("in({inner}): expected in(C x H x W) or in(D)")),
    }
}

fn parse_conv(core: &str) -> Result<(usize, String), SpecError> {
    // "conv3x3(...)" → (k, args); both kernel dims must agree.
    let body = core.strip_prefix("conv").unwrap_or(core);
    let open = body
        .find('(')
        .ok_or_else(|| SpecError(format!("conv item {core:?} missing (…)")))?;
    let (kk, rest) = body.split_at(open);
    let args = rest
        .strip_prefix('(')
        .and_then(|r| r.strip_suffix(')'))
        .ok_or_else(|| SpecError(format!("conv item {core:?} missing closing paren")))?;
    let (ka, kb) = kk
        .split_once('x')
        .ok_or_else(|| SpecError(format!("conv kernel {kk:?} must be KxK")))?;
    let (ka, kb) = (num(ka, "kernel")?, num(kb, "kernel")?);
    if ka != kb {
        return err(format!("only square kernels are supported, got {ka}x{kb}"));
    }
    Ok((ka, args.to_string()))
}

fn parse_item(tok: &str, first: bool) -> Result<Option<ItemSpec>, SpecError> {
    // Returns None for the `in(...)` pseudo-item (handled by the caller).
    let (core, name, pos) = split_mods(tok)?;
    if core.starts_with("in(") {
        if !first {
            return err("in(...) must be the first item");
        }
        return Ok(None);
    }
    let item = if core.starts_with("conv") {
        let (k, args) = parse_conv(core)?;
        let mut parts = args.split(',');
        let out_c = num(
            parts.next().filter(|s| !s.is_empty()).ok_or_else(|| {
                SpecError(format!("conv item {core:?} needs an output-channel count"))
            })?,
            "channels",
        )?;
        let (mut stride, mut pad, mut bn) = (1usize, k / 2, false);
        let mut bias: Option<bool> = None;
        for a in parts {
            match a {
                "bn" => bn = true,
                "bias" => bias = Some(true),
                "nobias" => bias = Some(false),
                _ if a.starts_with('s') => stride = num(&a[1..], "stride")?,
                _ if a.starts_with('p') => pad = num(&a[1..], "padding")?,
                other => return err(format!("unknown conv argument {other:?}")),
            }
        }
        ItemSpec::Conv {
            k,
            out_c,
            stride,
            pad,
            bias: bias.unwrap_or(!bn),
            bn,
            name,
            pos,
        }
    } else if let Some(rest) = core.strip_prefix("maxpool") {
        if name.is_some() || pos.is_some() {
            return err("maxpool takes no #name/@pos modifiers");
        }
        let (k, stride) = match rest.split_once('s') {
            Some((k, s)) => (num(k, "kernel")?, num(s, "stride")?),
            None => {
                let k = num(rest, "kernel")?;
                (k, k)
            }
        };
        ItemSpec::MaxPool { k, stride }
    } else if core == "gap" || core == "flatten" || core == "relu" {
        if name.is_some() || pos.is_some() {
            return err(format!("{core} takes no #name/@pos modifiers"));
        }
        match core {
            "gap" => ItemSpec::Gap,
            "flatten" => ItemSpec::Flatten,
            _ => ItemSpec::Relu,
        }
    } else if let Some(args) = strip_call(core, "fc") {
        let mut parts = args.split(',');
        let out = num(
            parts
                .next()
                .filter(|s| !s.is_empty())
                .ok_or_else(|| SpecError(format!("fc item {core:?} needs a width")))?,
            "width",
        )?;
        let (mut bn, mut bias) = (false, true);
        for a in parts {
            match a {
                "bn" => bn = true,
                "bias" => bias = true,
                "nobias" => bias = false,
                other => return err(format!("unknown fc argument {other:?}")),
            }
        }
        ItemSpec::Fc {
            out,
            bias,
            bn,
            name,
            pos,
        }
    } else if let Some(args) = strip_call(core, "res") {
        if pos.is_some() {
            return err("res takes no @pos modifier (blocks are always middle layers)");
        }
        let mut parts = args.split(',');
        let nw = parts
            .next()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| SpecError(format!("res item {core:?} needs NxW")))?;
        let (n, w) = nw
            .split_once('x')
            .ok_or_else(|| SpecError(format!("res shape {nw:?} must be NxW")))?;
        let (blocks, width) = (num(n, "block count")?, num(w, "width")?);
        let (mut expand, mut stride) = (None, None);
        for a in parts {
            match a {
                _ if a.starts_with('b') => expand = Some(num(&a[1..], "expansion")?),
                _ if a.starts_with('s') => stride = Some(num(&a[1..], "stride")?),
                other => return err(format!("unknown res argument {other:?}")),
            }
        }
        ItemSpec::Res {
            blocks,
            width,
            expand,
            stride,
            name,
        }
    } else {
        return err(format!(
            "unknown item {tok:?} (expected in/conv/maxpool/gap/flatten/relu/fc/res)"
        ));
    };
    Ok(Some(item))
}

fn parse_dash(s: &str) -> Result<(InputKind, Vec<ItemSpec>), SpecError> {
    let mut input: Option<InputKind> = None;
    let mut items = Vec::new();
    for (i, tok) in s.split('-').enumerate() {
        let tok = tok.trim();
        if tok.is_empty() {
            return err(format!("empty item in {s:?}"));
        }
        match parse_item(tok, i == 0)? {
            Some(item) => items.push(item),
            None => {
                let inner = strip_call(tok, "in")
                    .ok_or_else(|| SpecError(format!("malformed in(...) item {tok:?}")))?;
                input = Some(parse_input(inner)?);
            }
        }
    }
    let input = match input {
        Some(k) => k,
        None => {
            // Default: CIFAR-scale images; a leading fc needs an explicit
            // in(D).
            if matches!(items.first(), Some(ItemSpec::Fc { .. })) {
                return err("a spec starting with fc needs an explicit in(D) input item");
            }
            InputKind::Image { c: 3, h: 32, w: 32 }
        }
    };
    Ok((input, items))
}

/// `mlp(d0, hidden…, dn)` sugar → `in(d0)` + `fc(W[,bn])-relu` pairs +
/// `fc(dn)`.
fn parse_mlp(inner: &str) -> Result<(InputKind, Vec<ItemSpec>), SpecError> {
    let dims: Vec<&str> = inner.split(',').map(str::trim).collect();
    if dims.len() < 2 {
        return err(format!("mlp({inner}): need at least input and output dims"));
    }
    let input = InputKind::Vector {
        dim: num(dims[0], "mlp input dim")?,
    };
    let mut items = Vec::new();
    for hidden in &dims[1..dims.len() - 1] {
        let (bn, rest) = match hidden.strip_prefix("bn:") {
            Some(r) => (true, r),
            None => (false, *hidden),
        };
        let (width, repeat) = match rest.split_once('x') {
            Some((w, r)) => (num(w, "mlp width")?, num(r, "mlp repeat")?),
            None => (num(rest, "mlp width")?, 1),
        };
        check_dims(&[(repeat, "mlp repeat")])?;
        for _ in 0..repeat {
            items.push(ItemSpec::Fc {
                out: width,
                bias: true,
                bn,
                name: None,
                pos: None,
            });
            items.push(ItemSpec::Relu);
        }
    }
    items.push(ItemSpec::Fc {
        out: num(dims[dims.len() - 1], "mlp output dim")?,
        bias: true,
        bn: false,
        name: None,
        pos: None,
    });
    Ok((input, items))
}

// ---- builder ---------------------------------------------------------------

/// Programmatic spec construction; validated by [`SpecBuilder::finish`].
///
/// ```
/// use fp8train::nn::spec::SpecBuilder;
/// let spec = SpecBuilder::image(3, 32, 32)
///     .conv(3, 16).bn().named("stem")
///     .res(2, 32)
///     .gap()
///     .fc(10)
///     .finish()
///     .unwrap();
/// assert_eq!(spec.classes(), 10);
/// ```
#[derive(Clone, Debug)]
pub struct SpecBuilder {
    input: InputKind,
    items: Vec<ItemSpec>,
}

impl SpecBuilder {
    pub fn image(c: usize, h: usize, w: usize) -> Self {
        Self {
            input: InputKind::Image { c, h, w },
            items: Vec::new(),
        }
    }

    pub fn vector(dim: usize) -> Self {
        Self {
            input: InputKind::Vector { dim },
            items: Vec::new(),
        }
    }

    pub fn conv(mut self, k: usize, out_c: usize) -> Self {
        self.items.push(ItemSpec::Conv {
            k,
            out_c,
            stride: 1,
            pad: k / 2,
            bias: true,
            bn: false,
            name: None,
            pos: None,
        });
        self
    }

    pub fn maxpool(mut self, k: usize) -> Self {
        self.items.push(ItemSpec::MaxPool { k, stride: k });
        self
    }

    pub fn gap(mut self) -> Self {
        self.items.push(ItemSpec::Gap);
        self
    }

    pub fn flatten(mut self) -> Self {
        self.items.push(ItemSpec::Flatten);
        self
    }

    pub fn relu(mut self) -> Self {
        self.items.push(ItemSpec::Relu);
        self
    }

    pub fn fc(mut self, out: usize) -> Self {
        self.items.push(ItemSpec::Fc {
            out,
            bias: true,
            bn: false,
            name: None,
            pos: None,
        });
        self
    }

    pub fn res(mut self, blocks: usize, width: usize) -> Self {
        self.items.push(ItemSpec::Res {
            blocks,
            width,
            expand: None,
            stride: None,
            name: None,
        });
        self
    }

    pub fn bottleneck(mut self, blocks: usize, width: usize, expand: usize) -> Self {
        self.items.push(ItemSpec::Res {
            blocks,
            width,
            expand: Some(expand),
            stride: None,
            name: None,
        });
        self
    }

    /// Add BN to the last conv/fc item (convs also drop their bias, the
    /// conv-BN convention). Panics when the last item takes no BN.
    pub fn bn(mut self) -> Self {
        match self.items.last_mut() {
            Some(ItemSpec::Conv { bn, bias, .. }) => {
                *bn = true;
                *bias = false;
            }
            Some(ItemSpec::Fc { bn, .. }) => *bn = true,
            other => panic!("bn() needs a preceding conv/fc item, got {other:?}"),
        }
        self
    }

    /// Set the stride of the last conv/maxpool/res item.
    pub fn stride(mut self, s: usize) -> Self {
        match self.items.last_mut() {
            Some(ItemSpec::Conv { stride, .. }) | Some(ItemSpec::MaxPool { stride, .. }) => {
                *stride = s
            }
            Some(ItemSpec::Res { stride, .. }) => *stride = Some(s),
            other => panic!("stride() needs a preceding conv/maxpool/res item, got {other:?}"),
        }
        self
    }

    /// Set the padding of the last conv item.
    pub fn pad(mut self, p: usize) -> Self {
        match self.items.last_mut() {
            Some(ItemSpec::Conv { pad, .. }) => *pad = p,
            other => panic!("pad() needs a preceding conv item, got {other:?}"),
        }
        self
    }

    /// Drop the bias of the last conv/fc item.
    pub fn no_bias(mut self) -> Self {
        match self.items.last_mut() {
            Some(ItemSpec::Conv { bias, .. }) | Some(ItemSpec::Fc { bias, .. }) => *bias = false,
            other => panic!("no_bias() needs a preceding conv/fc item, got {other:?}"),
        }
        self
    }

    /// Name the last conv/fc/res item (overriding the stable-walk name).
    pub fn named(mut self, n: &str) -> Self {
        match self.items.last_mut() {
            Some(ItemSpec::Conv { name, .. })
            | Some(ItemSpec::Fc { name, .. })
            | Some(ItemSpec::Res { name, .. }) => *name = Some(n.to_string()),
            other => panic!("named() needs a preceding conv/fc/res item, got {other:?}"),
        }
        self
    }

    /// Override the precision position of the last conv/fc item — the
    /// generalized §4.1 first/last-layer lever.
    pub fn pos(mut self, p: LayerPos) -> Self {
        match self.items.last_mut() {
            Some(ItemSpec::Conv { pos, .. }) | Some(ItemSpec::Fc { pos, .. }) => *pos = Some(p),
            other => panic!("pos() needs a preceding conv/fc item, got {other:?}"),
        }
        self
    }

    /// Validate and seal the spec.
    pub fn finish(self) -> Result<ModelSpec, SpecError> {
        let spec = ModelSpec {
            preset: None,
            input: self.input,
            items: self.items,
        };
        spec.plan()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn presets_resolve_and_report_workload_shapes() {
        for id in ModelSpec::PRESET_IDS {
            let spec = ModelSpec::resolve(id).unwrap();
            assert_eq!(spec.preset_id(), Some(id));
            assert_eq!(spec.id(), id);
            let classes = if id == "bn50_dnn" { 30 } else { 10 };
            assert_eq!(spec.classes(), classes, "{id}");
            match spec.input() {
                InputKind::Vector { dim } => assert_eq!(dim, 440, "{id}"),
                InputKind::Image { c, h, w } => assert_eq!((c, h, w), (3, 32, 32), "{id}"),
            }
        }
        assert!(ModelSpec::resolve("not_a_model(").is_err());
    }

    #[test]
    fn presets_build_and_forward() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, false);
        for spec in ModelSpec::all_presets() {
            let mut m = spec.build(7);
            let x = Tensor::zeros(&spec.input().shape(2));
            let y = m.forward(x, &ctx);
            assert_eq!(y.shape, vec![2, spec.classes()], "{}", spec.id());
            assert!(m.num_params() > 1000, "{} too small", spec.id());
        }
    }

    #[test]
    fn lower_units_align_with_build() {
        // One LoweredUnit per built layer, in build order, for every
        // preset — the indexing contract the program executor relies on.
        for spec in ModelSpec::all_presets() {
            let model = spec.build(0);
            let units = spec.lower_units();
            assert_eq!(units.len(), model.layers.len(), "{}", spec.id());
        }
        // Structure spot-check on the conv preset: conv5x5(16) opens,
        // fc(10) closes, maxpools carry the walked shapes.
        let units = ModelSpec::cifar_cnn().lower_units();
        assert!(matches!(
            &units[0],
            LoweredUnit::Conv { name, out_c: 16, .. } if name == "conv1"
        ));
        assert!(matches!(
            units[1],
            LoweredUnit::Relu { per_example } if per_example == 16 * 28 * 28
        ));
        assert!(matches!(
            units[2],
            LoweredUnit::MaxPool { k: 2, stride: 2, c: 16, in_h: 28, in_w: 28 }
        ));
        assert!(matches!(
            units.last().unwrap(),
            LoweredUnit::Linear { name, out: 10, pos: LayerPos::Last, .. } if name == "fc"
        ));
        // And residual internals mirror the block builders.
        let resnet = ModelSpec::cifar_resnet().lower_units();
        let Some(LoweredUnit::Residual { main, shortcut, .. }) = resnet
            .iter()
            .find(|u| matches!(u, LoweredUnit::Residual { name, .. } if name == "s1b0"))
        else {
            panic!("s1b0 not lowered: {resnet:?}");
        };
        assert_eq!(main.len(), 5, "basic block main chain");
        assert_eq!(shortcut.len(), 2, "strided block needs a projection");
    }

    #[test]
    fn canonical_round_trips_every_preset() {
        for spec in ModelSpec::all_presets() {
            let printed = spec.canonical();
            let back = ModelSpec::parse(&printed)
                .unwrap_or_else(|e| panic!("{}: {printed} → {e}", spec.id()));
            assert_eq!(back, spec, "{}", spec.id());
            // And the canonical form is a fixed point.
            assert_eq!(back.canonical(), printed);
        }
    }

    #[test]
    fn mlp_sugar_lowers_to_fc_relu_chain() {
        let spec = ModelSpec::parse("mlp(784,bn:256x3,10)").unwrap();
        assert_eq!(spec.input(), InputKind::Vector { dim: 784 });
        assert_eq!(spec.classes(), 10);
        // 3 hidden (fc+relu) pairs + final fc.
        assert_eq!(spec.items().len(), 7);
        assert!(matches!(
            spec.items()[0],
            ItemSpec::Fc { out: 256, bn: true, .. }
        ));
        assert!(matches!(spec.items()[1], ItemSpec::Relu));
        assert!(matches!(
            spec.items()[6],
            ItemSpec::Fc { out: 10, bn: false, .. }
        ));
        // Equivalent dash form parses to the same spec.
        let dash = ModelSpec::parse(&spec.canonical()).unwrap();
        assert_eq!(dash, spec);
    }

    #[test]
    fn stable_walk_names_and_positions() {
        let spec = ModelSpec::parse("conv3x3(8)-maxpool2-conv3x3(16)-gap-fc(10)").unwrap();
        let mut m = spec.build(1);
        let mut names = Vec::new();
        m.visit_params(&mut |p| names.push(p.name.clone()));
        assert_eq!(
            names,
            vec!["conv1.w", "conv1.b", "conv2.w", "conv2.b", "fc.w", "fc.b"]
        );
        // Multiple fcs number fc1..fcN.
        let spec = ModelSpec::parse("in(12)-fc(8)-relu-fc(4)").unwrap();
        let mut m = spec.build(1);
        let mut names = Vec::new();
        m.visit_params(&mut |p| names.push(p.name.clone()));
        assert_eq!(names, vec!["fc1.w", "fc1.b", "fc2.w", "fc2.b"]);
    }

    #[test]
    fn pos_overrides_and_defaults() {
        // Default: first GEMM First, last GEMM Last.
        let spec = ModelSpec::parse("in(8)-fc(8)-relu-fc(8)-relu-fc(4)").unwrap();
        let plan = spec.plan().unwrap();
        let fc_pos: Vec<LayerPos> = plan
            .steps
            .iter()
            .filter_map(|s| match s {
                PlanStep::Fc { pos, .. } => Some(*pos),
                _ => None,
            })
            .collect();
        assert_eq!(fc_pos, vec![LayerPos::First, LayerPos::Middle, LayerPos::Last]);
        // A single GEMM layer is Last.
        let spec = ModelSpec::parse("in(8)-fc(4)").unwrap();
        let plan = spec.plan().unwrap();
        assert!(matches!(
            plan.steps[0],
            PlanStep::Fc { pos: LayerPos::Last, .. }
        ));
        // Explicit override wins — the generalized Table 3 lever.
        let spec = ModelSpec::parse("in(8)-fc(8)-relu-fc(4)@middle").unwrap();
        let plan = spec.plan().unwrap();
        assert!(matches!(
            plan.steps.last().unwrap(),
            PlanStep::Fc { pos: LayerPos::Middle, .. }
        ));
    }

    #[test]
    fn shape_inference_tracks_conv_geometry() {
        // 3x32x32 → conv s2 → 16x16 → maxpool2 → 8x8 → flatten = 16·64.
        let spec = ModelSpec::parse("conv3x3(16,s2)-maxpool2-flatten-fc(10)").unwrap();
        let mut m = spec.build(3);
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, false);
        let y = m.forward(Tensor::zeros(&[2, 3, 32, 32]), &ctx);
        assert_eq!(y.shape, vec![2, 10]);
        // The auto-flatten path gives the same dims without `flatten`.
        let auto = ModelSpec::parse("conv3x3(16,s2)-maxpool2-fc(10)").unwrap();
        assert_eq!(auto.classes(), 10);
        let mut m2 = auto.build(3);
        let y2 = m2.forward(Tensor::zeros(&[2, 3, 32, 32]), &ctx);
        assert_eq!(y2.shape, vec![2, 10]);
    }

    #[test]
    fn res_stage_stride_pattern_and_override() {
        let spec = ModelSpec::parse("conv3x3(16,bn)#stem-res(2x16)-res(2x32)-gap-fc(10)").unwrap();
        let plan = spec.plan().unwrap();
        let strides: Vec<usize> = plan
            .steps
            .iter()
            .filter_map(|s| match s {
                PlanStep::Block { stride, .. } => Some(*stride),
                _ => None,
            })
            .collect();
        assert_eq!(strides, vec![1, 1, 2, 1]);
        // Explicit sN pins the stage-entry stride.
        let spec = ModelSpec::parse("conv3x3(16,bn)-res(2x32,s1)-gap-fc(10)").unwrap();
        let plan = spec.plan().unwrap();
        let strides: Vec<usize> = plan
            .steps
            .iter()
            .filter_map(|s| match s {
                PlanStep::Block { stride, .. } => Some(*stride),
                _ => None,
            })
            .collect();
        assert_eq!(strides, vec![1, 1]);
    }

    #[test]
    fn builder_matches_parsed_spec() {
        let built = SpecBuilder::image(3, 32, 32)
            .conv(3, 16)
            .bn()
            .named("stem")
            .res(2, 16)
            .res(2, 32)
            .gap()
            .fc(10)
            .named("fc")
            .finish()
            .unwrap();
        let parsed =
            ModelSpec::parse("conv3x3(16,bn)#stem-res(2x16)-res(2x32)-gap-fc(10)#fc").unwrap();
        assert_eq!(built, parsed);
        assert_eq!(built.canonical(), parsed.canonical());
    }

    #[test]
    fn malformed_specs_are_rejected_with_context() {
        for (spec, why) in [
            ("", "empty"),
            ("conv3x3(16)", "no 2-D output"),
            ("fc(10)", "fc without in(D)"),
            ("in(10)-conv3x3(4)-fc(2)", "conv on a vector"),
            ("in(3x4x4)-maxpool8-fc(2)", "pool exceeds input"),
            ("conv3x4(8)-gap-fc(2)", "non-square kernel"),
            ("in(3x4x4)-conv9x9(8,p0)-gap-fc(2)", "kernel exceeds padded input"),
            ("in(3x8x4)-res(1x8)-gap-fc(2)", "res needs square input"),
            ("conv3x3(0)-gap-fc(2)", "zero channels"),
            ("conv3x3(8)#a-conv3x3(8)#a-gap-fc(2)", "duplicate names"),
            ("conv3x3(8)#s0b0.c1-res(1x8)-gap-fc(2)", "collides with block-internal names"),
            ("res(1x8)#a-res(1x8)#a-gap-fc(2)", "duplicate stage names collide at block level"),
            ("conv3x3(8,zz)-gap-fc(2)", "unknown conv arg"),
            ("warp(9)-fc(2)", "unknown item"),
            ("conv3x3(8)-gap-fc(2)@sideways", "unknown position"),
            ("conv3x3(8)-gap-fc(2)#bad name", "bad name chars"),
            ("mlp(10)", "mlp needs two dims"),
            ("mlp(10,bn:,5)", "mlp bad hidden"),
            ("gap-in(3x8x8)-fc(2)", "in not first"),
            ("conv3x3(8)--gap-fc(2)", "empty item"),
            ("res(1x8)-gap-fc(2)@first#x", "mods in wrong order"),
        ] {
            assert!(ModelSpec::parse(spec).is_err(), "{why}: {spec:?} parsed");
        }
    }

    #[test]
    fn spec_models_train_a_step() {
        // A fully custom spec trains end-to-end through the layer stack.
        let spec = ModelSpec::parse("mlp(12,bn:8,4)").unwrap();
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut m = spec.build(5);
        let x = Tensor::from_vec(&[2, 12], (0..24).map(|i| 0.1 * i as f32).collect());
        let y = m.forward(x, &ctx);
        assert_eq!(y.shape, vec![2, 4]);
        let dx = m.backward(Tensor::full(&[2, 4], 0.1), &ctx);
        assert_eq!(dx.shape, vec![2, 12]);
    }

    #[test]
    fn template_expansion_order_and_validity() {
        // No placeholder → identity.
        assert_eq!(
            ModelSpec::expand_template("cifar_cnn").unwrap(),
            vec!["cifar_cnn"]
        );
        // Leftmost axis varies slowest; every expansion parses.
        let got = ModelSpec::expand_template("mlp(8,{4,6}x{1,2},3)").unwrap();
        assert_eq!(
            got,
            vec![
                "mlp(8,4x1,3)",
                "mlp(8,4x2,3)",
                "mlp(8,6x1,3)",
                "mlp(8,6x2,3)"
            ]
        );
        for s in &got {
            ModelSpec::resolve(s).unwrap();
        }
        // A position axis is just another alternation.
        let got = ModelSpec::expand_template("in(8)-fc(6)-relu-fc(4)@{middle,last}").unwrap();
        assert_eq!(got.len(), 2);
        assert!(got[0].ends_with("@middle") && got[1].ends_with("@last"));
        // Deterministic: same template → same list.
        assert_eq!(got, ModelSpec::expand_template("in(8)-fc(6)-relu-fc(4)@{middle,last}").unwrap());
    }

    #[test]
    fn template_expansion_rejects_malformed_and_huge() {
        for (tpl, why) in [
            ("conv3x3({8,16)-gap-fc(2)", "unmatched open"),
            ("conv3x3(8})-gap-fc(2)", "unmatched close"),
            ("conv3x3(8)}-{gap-fc(2)", "close before open"),
            ("conv3x3({8,{16}})-gap-fc(2)", "nested"),
            ("conv3x3({})-gap-fc(2)", "empty placeholder"),
            ("conv3x3({8,})-gap-fc(2)", "empty alternative"),
        ] {
            assert!(ModelSpec::expand_template(tpl).is_err(), "{why}: {tpl:?}");
        }
        // 8^5 = 32768 > MAX_TEMPLATE_EXPANSIONS: refused, not allocated.
        let axis = "{1,2,3,4,5,6,7,8}";
        let huge = format!("mlp(8,{axis}x{axis},{axis}x{axis},{axis},3)");
        assert!(ModelSpec::expand_template(&huge).is_err());
    }

    #[test]
    fn pos_override_rewrites_last_gemm_item() {
        // The last GEMM item of a preset flips Last → Middle (the Table 3
        // lever), re-validates, and round-trips through the canonical DSL.
        let spec = ModelSpec::cifar_resnet().with_pos_override(LayerPos::Middle).unwrap();
        assert_eq!(spec.preset_id(), None);
        let plan = spec.plan().unwrap();
        let last_fc = plan
            .steps
            .iter()
            .rev()
            .find_map(|s| match s {
                PlanStep::Fc { pos, .. } => Some(*pos),
                _ => None,
            })
            .unwrap();
        assert_eq!(last_fc, LayerPos::Middle);
        assert!(spec.canonical().contains("@middle"));
        let back = ModelSpec::parse(&spec.canonical()).unwrap();
        assert_eq!(back, spec);
        // A spec with no GEMM item cannot be overridden.
        let gapless = ModelSpec::parse("in(3x4x4)-gap").unwrap();
        assert!(gapless.with_pos_override(LayerPos::Last).is_err());
    }

    #[test]
    fn file_stem_is_filesystem_safe() {
        assert_eq!(ModelSpec::cifar_cnn().file_stem(), "cifar_cnn");
        let spec = ModelSpec::parse("conv3x3(8)-gap-fc(2)").unwrap();
        let stem = spec.file_stem();
        assert!(stem.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'));
        assert!(stem.len() <= 48);
    }
}
