//! Batch normalization (2-D over channels, 1-D over features).
//!
//! BN is kept in full precision: the paper quantizes the GEMM data path
//! (weights / activations / errors / gradients) and the weight-update
//! AXPYs, but BN's reductions and per-channel affine transform are not
//! GEMMs and contribute negligible FLOPs — the same treatment every
//! mixed-precision framework (MPT [16], DFP [4]) applies. BN's γ/β *are*
//! learnable parameters and therefore flow through the FP16-SR update path
//! like every other parameter.

use super::quant::QuantCtx;
use super::{Layer, Param};
use crate::state::{self, StateError, StateMap};
use crate::tensor::{scratch, Tensor};

pub struct BatchNorm {
    pub gamma: Param,
    pub beta: Param,
    pub running_mean: Vec<f32>,
    pub running_var: Vec<f32>,
    pub momentum: f32,
    pub eps: f32,
    channels: usize,
    /// `true` → NCHW input, stats over N·H·W per channel;
    /// `false` → [N, F] input, stats over N per feature.
    spatial: bool,
    // backward caches
    x_hat: Vec<f32>,
    inv_std: Vec<f32>,
    in_shape: Vec<usize>,
}

impl BatchNorm {
    pub fn new_2d(name: &str, channels: usize) -> Self {
        Self::new(name, channels, true)
    }

    pub fn new_1d(name: &str, features: usize) -> Self {
        Self::new(name, features, false)
    }

    fn new(name: &str, channels: usize, spatial: bool) -> Self {
        Self {
            gamma: Param::new(format!("{name}.gamma"), Tensor::full(&[channels], 1.0), false),
            beta: Param::new(format!("{name}.beta"), Tensor::zeros(&[channels]), false),
            running_mean: vec![0.0; channels],
            running_var: vec![1.0; channels],
            momentum: 0.9,
            eps: 1e-5,
            channels,
            spatial,
            x_hat: vec![],
            inv_std: vec![],
            in_shape: vec![],
        }
    }

    /// Iterate (channel, flat index) pairs of the input layout.
    #[inline]
    fn for_each<F: FnMut(usize, usize)>(&self, shape: &[usize], mut f: F) {
        if self.spatial {
            let (n, c, hw) = (shape[0], shape[1], shape[2] * shape[3]);
            for img in 0..n {
                for ch in 0..c {
                    let base = (img * c + ch) * hw;
                    for s in 0..hw {
                        f(ch, base + s);
                    }
                }
            }
        } else {
            let (n, c) = (shape[0], shape[1]);
            for img in 0..n {
                for ch in 0..c {
                    f(ch, img * c + ch);
                }
            }
        }
    }

    fn count_per_channel(&self, shape: &[usize]) -> f32 {
        if self.spatial {
            (shape[0] * shape[2] * shape[3]) as f32
        } else {
            shape[0] as f32
        }
    }
}

impl Layer for BatchNorm {
    fn forward(&mut self, mut x: Tensor, ctx: &QuantCtx) -> Tensor {
        let shape = x.shape.clone();
        let c = self.channels;
        if self.spatial {
            assert_eq!(shape[1], c, "BN channel mismatch");
        } else {
            assert_eq!(shape[1], c, "BN feature mismatch");
        }
        let m = self.count_per_channel(&shape);

        // The per-channel reduction vectors and the normalized-activation
        // cache are step-local recurring temporaries → scratch arena
        // (leases are zero-filled, so results are bit-identical to fresh
        // allocations — the ROADMAP "extend the arena to the BN scratch
        // vectors" lever).
        let (mean, var) = if ctx.train {
            let mut mean = scratch::take(c);
            self.for_each(&shape, |ch, i| mean[ch] += x.data[i]);
            for v in &mut mean {
                *v /= m;
            }
            let mut var = scratch::take(c);
            self.for_each(&shape, |ch, i| {
                let d = x.data[i] - mean[ch];
                var[ch] += d * d;
            });
            for v in &mut var {
                *v /= m;
            }
            for ch in 0..c {
                self.running_mean[ch] =
                    self.momentum * self.running_mean[ch] + (1.0 - self.momentum) * mean[ch];
                self.running_var[ch] =
                    self.momentum * self.running_var[ch] + (1.0 - self.momentum) * var[ch];
            }
            (mean, var)
        } else {
            let mut mean = scratch::take(c);
            mean.copy_from_slice(&self.running_mean);
            let mut var = scratch::take(c);
            var.copy_from_slice(&self.running_var);
            (mean, var)
        };

        let mut inv_std = scratch::take(c);
        for (o, &v) in inv_std.iter_mut().zip(&var) {
            *o = 1.0 / (v + self.eps).sqrt();
        }
        let mut x_hat = scratch::take(x.len());
        let (g, b) = (&self.gamma.value.data, &self.beta.value.data);
        self.for_each(&shape, |ch, i| {
            let h = (x.data[i] - mean[ch]) * inv_std[ch];
            x_hat[i] = h;
            x.data[i] = g[ch] * h + b[ch];
        });
        scratch::recycle(mean);
        scratch::recycle(var);
        if ctx.train {
            scratch::recycle(std::mem::replace(&mut self.x_hat, x_hat));
            scratch::recycle(std::mem::replace(&mut self.inv_std, inv_std));
            self.in_shape = shape;
        } else {
            scratch::recycle(x_hat);
            scratch::recycle(inv_std);
        }
        x
    }

    fn backward(&mut self, mut dy: Tensor, _ctx: &QuantCtx) -> Tensor {
        let shape = self.in_shape.clone();
        assert_eq!(dy.shape, shape, "BN backward shape");
        let c = self.channels;
        let m = self.count_per_channel(&shape);

        // Per-channel reductions: Σdy and Σdy·x̂ (arena-leased, zeroed).
        let mut sum_dy = scratch::take(c);
        let mut sum_dyh = scratch::take(c);
        self.for_each(&shape, |ch, i| {
            sum_dy[ch] += dy.data[i];
            sum_dyh[ch] += dy.data[i] * self.x_hat[i];
        });
        for ch in 0..c {
            self.beta.grad.data[ch] += sum_dy[ch];
            self.gamma.grad.data[ch] += sum_dyh[ch];
        }

        // dx = (γ·inv_std/m) · (m·dy − Σdy − x̂·Σ(dy·x̂))
        let g = &self.gamma.value.data;
        let x_hat = &self.x_hat;
        let inv_std = &self.inv_std;
        self.for_each(&shape, |ch, i| {
            dy.data[i] = g[ch] * inv_std[ch] / m
                * (m * dy.data[i] - sum_dy[ch] - x_hat[i] * sum_dyh[ch]);
        });
        scratch::recycle(sum_dy);
        scratch::recycle(sum_dyh);
        // The forward caches' lifetime ends here — back to the arena.
        scratch::recycle(std::mem::take(&mut self.x_hat));
        scratch::recycle(std::mem::take(&mut self.inv_std));
        dy
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.gamma);
        f(&mut self.beta);
    }

    fn name(&self) -> String {
        self.gamma.name.trim_end_matches(".gamma").to_string()
    }

    fn invalidate_backward_state(&mut self) {
        // The eval branch of `forward` recycles its *own* scratch but
        // leaves these caches from the last training batch untouched; a
        // backward would consume them. Clearing `in_shape` makes the shape
        // assert in `backward` fire instead.
        scratch::recycle(std::mem::take(&mut self.x_hat));
        scratch::recycle(std::mem::take(&mut self.inv_std));
        self.in_shape.clear();
    }

    /// Running statistics are eval-time state (the forward pass consumes
    /// them whenever `ctx.train` is false), so they checkpoint alongside
    /// the learnable γ/β. Raw f32 → stored as exact bits.
    fn save_extra_state(&mut self, prefix: &str, out: &mut StateMap) {
        let base = self.name();
        let c = self.channels;
        out.put_tensor(
            &state::key(prefix, &format!("{base}.running_mean")),
            &[c],
            &self.running_mean,
        );
        out.put_tensor(
            &state::key(prefix, &format!("{base}.running_var")),
            &[c],
            &self.running_var,
        );
    }

    fn load_extra_state(&mut self, prefix: &str, src: &StateMap) -> Result<(), StateError> {
        let base = self.name();
        let c = self.channels;
        src.copy_tensor_into(
            &state::key(prefix, &format!("{base}.running_mean")),
            &[c],
            &mut self.running_mean,
        )?;
        src.copy_tensor_into(
            &state::key(prefix, &format!("{base}.running_var")),
            &[c],
            &mut self.running_var,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::numerics::Xoshiro256;

    #[test]
    fn normalizes_batch_statistics() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut bn = BatchNorm::new_2d("bn", 2);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let x = Tensor::from_vec(
            &[4, 2, 3, 3],
            (0..72).map(|_| rng.uniform(-3.0, 7.0)).collect(),
        );
        let y = bn.forward(x, &ctx);
        // Per-channel mean ≈ 0, var ≈ 1 after normalization (γ=1, β=0).
        for ch in 0..2 {
            let vals: Vec<f32> = (0..4)
                .flat_map(|n| {
                    let base = (n * 2 + ch) * 9;
                    y.data[base..base + 9].to_vec()
                })
                .collect();
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let var: f32 =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 1e-5, "mean={mean}");
            assert!((var - 1.0).abs() < 1e-3, "var={var}");
        }
    }

    #[test]
    fn eval_uses_running_stats() {
        let policy = PrecisionPolicy::fp32();
        let train = QuantCtx::new(&policy, 0, true);
        let eval = QuantCtx::new(&policy, 0, false);
        let mut bn = BatchNorm::new_2d("bn", 1);
        let mut rng = Xoshiro256::seed_from_u64(2);
        // Many training batches drive running stats toward (2, 4).
        for _ in 0..200 {
            let x = Tensor::from_vec(
                &[8, 1, 2, 2],
                (0..32).map(|_| 2.0 + 2.0 * rng.normal()).collect(),
            );
            bn.forward(x, &train);
        }
        assert!((bn.running_mean[0] - 2.0).abs() < 0.3);
        assert!((bn.running_var[0] - 4.0).abs() < 1.0);
        // Eval mode with a constant input uses running stats, not batch.
        let y = bn.forward(Tensor::full(&[1, 1, 2, 2], 2.0), &eval);
        assert!(y.data.iter().all(|&v| v.abs() < 0.3), "y={:?}", y.data);
    }

    #[test]
    fn bn_gradcheck() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let x = Tensor::from_vec(&[3, 2, 2, 2], (0..24).map(|_| rng.uniform(-1.0, 1.0)).collect());
        let dy = Tensor::from_vec(&[3, 2, 2, 2], (0..24).map(|_| rng.uniform(-1.0, 1.0)).collect());

        let mut bn = BatchNorm::new_2d("bn", 2);
        bn.forward(x.clone(), &ctx);
        let dx = bn.backward(dy.clone(), &ctx);

        let loss = |x: &Tensor| -> f32 {
            let mut b = BatchNorm::new_2d("bn", 2);
            let y = b.forward(x.clone(), &ctx);
            y.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-3;
        for i in (0..24).step_by(5) {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 2e-2,
                "dx[{i}]: numeric {num} vs {}",
                dx.data[i]
            );
        }
    }

    #[test]
    fn state_dict_round_trips_running_stats() {
        use crate::state::{StateDict, StateMap};
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut bn = BatchNorm::new_2d("bn", 2);
        let mut rng = Xoshiro256::seed_from_u64(5);
        for _ in 0..5 {
            let x = Tensor::from_vec(
                &[4, 2, 2, 2],
                (0..32).map(|_| rng.uniform(-2.0, 2.0)).collect(),
            );
            bn.forward(x, &ctx);
        }
        bn.gamma.value.data[0] = 1.5;
        let mut map = StateMap::new();
        bn.save_state("model", &mut map);
        let mut fresh = BatchNorm::new_2d("bn", 2);
        fresh.load_state("model", &map).unwrap();
        assert_eq!(fresh.running_mean, bn.running_mean);
        assert_eq!(fresh.running_var, bn.running_var);
        assert_eq!(fresh.gamma.value.data, bn.gamma.value.data);
        assert_eq!(fresh.beta.value.data, bn.beta.value.data);
        // A differently-named layer can't silently absorb these entries.
        assert!(BatchNorm::new_2d("other", 2).load_state("model", &map).is_err());
    }

    #[test]
    fn bn_1d_shapes() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut bn = BatchNorm::new_1d("bn", 5);
        let y = bn.forward(Tensor::zeros(&[3, 5]), &ctx);
        assert_eq!(y.shape, vec![3, 5]);
        let dx = bn.backward(Tensor::zeros(&[3, 5]), &ctx);
        assert_eq!(dx.shape, vec![3, 5]);
    }
}
