//! Activation layers. The paper's six models use ReLU exclusively
//! (Appendix A); activations are elementwise and stay in full precision —
//! quantization happens where tensors are *stored* at GEMM boundaries.

use super::quant::QuantCtx;
use super::Layer;
use crate::tensor::Tensor;

/// Rectified linear unit, `y = max(0, x)`; backward masks by the sign of
/// the cached input.
pub struct Relu {
    mask: Vec<bool>,
}

impl Relu {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { mask: vec![] }
    }
}

impl Layer for Relu {
    fn forward(&mut self, mut x: Tensor, ctx: &QuantCtx) -> Tensor {
        if ctx.train {
            self.mask = x.data.iter().map(|&v| v > 0.0).collect();
        }
        for v in &mut x.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        x
    }

    fn backward(&mut self, mut dy: Tensor, _ctx: &QuantCtx) -> Tensor {
        assert_eq!(dy.len(), self.mask.len(), "relu backward shape");
        for (v, &m) in dy.data.iter_mut().zip(&self.mask) {
            if !m {
                *v = 0.0;
            }
        }
        dy
    }

    fn name(&self) -> String {
        "relu".into()
    }

    fn invalidate_backward_state(&mut self) {
        // Without this, an eval forward between a train forward and its
        // backward would leave the *previous training batch's* mask in
        // place — and when the batch sizes coincide, the shape assert above
        // cannot catch the mixup.
        self.mask.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};

    #[test]
    fn relu_forward_backward() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut r = Relu::new();
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.0, 2.0, -3.0]);
        let y = r.forward(x, &ctx);
        assert_eq!(y.data, vec![0.0, 0.0, 2.0, 0.0]);
        let dy = Tensor::from_vec(&[1, 4], vec![1.0, 1.0, 1.0, 1.0]);
        let dx = r.backward(dy, &ctx);
        // Gradient passes only where x > 0 (x == 0 blocked).
        assert_eq!(dx.data, vec![0.0, 0.0, 1.0, 0.0]);
    }
}
