//! Pooling layers: max pooling (AlexNet/CIFAR-CNN) and global average
//! pooling (ResNet heads). Elementwise/reduction ops stay in full
//! precision, as in the paper's emulation (only GEMMs and updates are
//! reduced).

use super::quant::QuantCtx;
use super::Layer;
use crate::tensor::Tensor;

/// kxk max pooling with stride `s` (no padding).
pub struct MaxPool2d {
    pub k: usize,
    pub stride: usize,
    argmax: Vec<usize>,
    in_shape: Vec<usize>,
}

impl MaxPool2d {
    pub fn new(k: usize, stride: usize) -> Self {
        Self {
            k,
            stride,
            argmax: vec![],
            in_shape: vec![],
        }
    }

    fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        ((h - self.k) / self.stride + 1, (w - self.k) / self.stride + 1)
    }
}

impl Layer for MaxPool2d {
    fn forward(&mut self, x: Tensor, ctx: &QuantCtx) -> Tensor {
        assert_eq!(x.ndim(), 4);
        let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let (oh, ow) = self.out_hw(h, w);
        let mut out = Tensor::zeros(&[n, c, oh, ow]);
        let mut argmax = vec![0usize; out.len()];
        for img in 0..n {
            for ch in 0..c {
                let plane = (img * c + ch) * h * w;
                let oplane = (img * c + ch) * oh * ow;
                for oy in 0..oh {
                    for ox in 0..ow {
                        // Seed the scan from the window's own first element:
                        // a window that is all-NaN or all -inf must keep its
                        // argmax inside the window (a 0-initialized flat
                        // index would route the backward gradient to element
                        // 0 of image 0, channel 0).
                        let first = plane + oy * self.stride * w + ox * self.stride;
                        let mut best = x.data[first];
                        let mut best_i = first;
                        for ky in 0..self.k {
                            for kx in 0..self.k {
                                let i = plane + (oy * self.stride + ky) * w + ox * self.stride + kx;
                                let v = x.data[i];
                                // NaN-safe: a NaN candidate never wins over a
                                // comparable value; a NaN incumbent loses to
                                // the first comparable value.
                                if (best.is_nan() && !v.is_nan()) || v > best {
                                    best = v;
                                    best_i = i;
                                }
                            }
                        }
                        out.data[oplane + oy * ow + ox] = best;
                        argmax[oplane + oy * ow + ox] = best_i;
                    }
                }
            }
        }
        if ctx.train {
            self.argmax = argmax;
            self.in_shape = x.shape.clone();
        }
        // Eval-mode invalidation of the saved argmax/shape is hoisted into
        // the `Sequential` forward walk (`invalidate_backward_state`),
        // which covers every layer kind in one place.
        out
    }

    fn backward(&mut self, dy: Tensor, _ctx: &QuantCtx) -> Tensor {
        assert!(
            !self.in_shape.is_empty() && self.argmax.len() == dy.len(),
            "maxpool backward without a matching train-mode forward \
             (saved argmax covers {} elements, dy has {})",
            self.argmax.len(),
            dy.len()
        );
        let mut dx = Tensor::zeros(&self.in_shape.clone());
        for (i, &src) in self.argmax.iter().enumerate() {
            dx.data[src] += dy.data[i];
        }
        dx
    }

    fn name(&self) -> String {
        format!("maxpool{}x{}", self.k, self.k)
    }

    fn invalidate_backward_state(&mut self) {
        self.argmax.clear();
        self.in_shape.clear();
    }
}

/// Global average pooling: NCHW → [N, C].
pub struct GlobalAvgPool {
    in_shape: Vec<usize>,
}

impl GlobalAvgPool {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { in_shape: vec![] }
    }
}

impl Layer for GlobalAvgPool {
    fn forward(&mut self, x: Tensor, ctx: &QuantCtx) -> Tensor {
        assert_eq!(x.ndim(), 4);
        let (n, c, hw) = (x.shape[0], x.shape[1], x.shape[2] * x.shape[3]);
        let mut out = Tensor::zeros(&[n, c]);
        for img in 0..n {
            for ch in 0..c {
                let base = (img * c + ch) * hw;
                let s: f32 = x.data[base..base + hw].iter().sum();
                out.data[img * c + ch] = s / hw as f32;
            }
        }
        if ctx.train {
            self.in_shape = x.shape.clone();
        }
        out
    }

    fn backward(&mut self, dy: Tensor, _ctx: &QuantCtx) -> Tensor {
        assert!(
            !self.in_shape.is_empty(),
            "gap backward without a matching train-mode forward"
        );
        let shape = self.in_shape.clone();
        let (n, c, hw) = (shape[0], shape[1], shape[2] * shape[3]);
        assert_eq!(
            dy.len(),
            n * c,
            "gap backward: dy has {} elements, saved input shape {:?} implies {}",
            dy.len(),
            shape,
            n * c
        );
        let mut dx = Tensor::zeros(&shape);
        for img in 0..n {
            for ch in 0..c {
                let g = dy.data[img * c + ch] / hw as f32;
                let base = (img * c + ch) * hw;
                for v in &mut dx.data[base..base + hw] {
                    *v = g;
                }
            }
        }
        dx
    }

    fn name(&self) -> String {
        "gap".into()
    }

    fn invalidate_backward_state(&mut self) {
        self.in_shape.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};

    #[test]
    fn maxpool_picks_max_and_routes_grad() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut p = MaxPool2d::new(2, 2);
        let x = Tensor::from_vec(
            &[1, 1, 4, 4],
            vec![
                1., 2., 5., 6., //
                3., 4., 7., 8., //
                9., 10., 13., 14., //
                11., 12., 15., 16.,
            ],
        );
        let y = p.forward(x, &ctx);
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![4., 8., 12., 16.]);
        let dy = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let dx = p.backward(dy, &ctx);
        // Gradient lands only at the argmax positions.
        assert_eq!(dx.data[5], 1.0); // value 4
        assert_eq!(dx.data[7], 2.0); // value 8
        assert_eq!(dx.data[13], 3.0); // value 12
        assert_eq!(dx.data[15], 4.0); // value 16
        assert_eq!(dx.data.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    fn gap_averages_and_spreads() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut g = GlobalAvgPool::new();
        let x = Tensor::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 10., 10., 10., 10.]);
        let y = g.forward(x, &ctx);
        assert_eq!(y.shape, vec![1, 2]);
        assert_eq!(y.data, vec![2.5, 10.0]);
        let dx = g.backward(Tensor::from_vec(&[1, 2], vec![4.0, 8.0]), &ctx);
        assert_eq!(dx.data, vec![1., 1., 1., 1., 2., 2., 2., 2.]);
    }

    #[test]
    fn maxpool_nan_and_neg_inf_windows_stay_in_window() {
        // Regression: best_i used to start at flat index 0, so an all-NaN
        // or all -inf window routed its gradient to element 0 of the whole
        // buffer (image 0, channel 0).
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut p = MaxPool2d::new(2, 2);
        let nan = f32::NAN;
        let ninf = f32::NEG_INFINITY;
        let x = Tensor::from_vec(
            &[1, 1, 4, 4],
            vec![
                1., 2., nan, nan, //
                3., 4., nan, nan, //
                ninf, ninf, 5., nan, //
                ninf, ninf, 6., 7.,
            ],
        );
        let y = p.forward(x, &ctx);
        assert_eq!(y.data[0], 4.0); // finite window unaffected
        assert!(y.data[1].is_nan()); // all-NaN window forwards NaN
        assert_eq!(y.data[2], ninf); // all -inf window forwards -inf
        assert_eq!(y.data[3], 7.0); // NaN candidates never beat finite ones
        let dy = Tensor::from_vec(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let dx = p.backward(dy, &ctx);
        // Element 0 only receives the finite window's gradient — nothing
        // leaks from the degenerate windows.
        assert_eq!(dx.data[0], 0.0);
        assert_eq!(dx.data[5], 1.0); // value 4
        assert_eq!(dx.data[2], 2.0); // all-NaN window → its first element
        assert_eq!(dx.data[8], 3.0); // all -inf window → its first element
        assert_eq!(dx.data[15], 4.0); // value 7
        assert_eq!(dx.data.iter().filter(|&&v| v != 0.0).count(), 4);
    }

    #[test]
    #[should_panic(expected = "maxpool backward without a matching train-mode forward")]
    fn maxpool_backward_after_eval_forward_panics() {
        // Eval-mode invalidation is now owned by the `Sequential` walk, so
        // the hazard is exercised through a container (as engines do).
        let policy = PrecisionPolicy::fp32();
        let train = QuantCtx::new(&policy, 0, true);
        let eval = QuantCtx::new(&policy, 0, false);
        let mut model = crate::nn::Sequential::new(vec![Box::new(MaxPool2d::new(2, 2))]);
        // A train forward on a *different* batch shape plants stale state…
        model.forward(Tensor::zeros(&[2, 1, 4, 4]), &train);
        // …the eval forward must invalidate it, so this backward asserts
        // instead of silently misrouting gradients through the old argmax.
        model.forward(Tensor::zeros(&[1, 1, 4, 4]), &eval);
        model.backward(Tensor::zeros(&[1, 1, 2, 2]), &eval);
    }

    #[test]
    #[should_panic(expected = "gap backward without a matching train-mode forward")]
    fn gap_backward_after_eval_forward_panics() {
        let policy = PrecisionPolicy::fp32();
        let train = QuantCtx::new(&policy, 0, true);
        let eval = QuantCtx::new(&policy, 0, false);
        let mut model = crate::nn::Sequential::new(vec![Box::new(GlobalAvgPool::new())]);
        model.forward(Tensor::zeros(&[2, 3, 2, 2]), &train);
        model.forward(Tensor::zeros(&[1, 3, 2, 2]), &eval);
        model.backward(Tensor::zeros(&[1, 3]), &eval);
    }

    #[test]
    fn maxpool_overlapping_window() {
        // AlexNet-style 3x3/stride-2 pooling.
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut p = MaxPool2d::new(3, 2);
        let y = p.forward(Tensor::zeros(&[2, 3, 7, 7]), &ctx);
        assert_eq!(y.shape, vec![2, 3, 3, 3]);
    }
}
