//! Softmax cross-entropy loss with loss scaling.
//!
//! §4.1: the last layer is sensitive because Softmax exponentially
//! amplifies logit errors — the paper keeps the Softmax *input* in FP16
//! (Table 3 shows FP8 there costs 10% accuracy). §3: the back-propagated
//! error is scaled by a single factor (1000) to preserve small-magnitude
//! gradients in FP8/FP16 ranges; the optimizer divides it back out before
//! the weight update.

use crate::numerics::{FloatFormat, RoundMode};
use crate::tensor::Tensor;

/// Output of [`softmax_xent`].
pub struct LossOut {
    /// Mean cross-entropy over the batch (natural log), full precision.
    pub loss: f64,
    /// Number of correct argmax predictions.
    pub correct: usize,
    /// `dL/dlogits`, already multiplied by `loss_scale` and divided by the
    /// batch size — feed straight into the model's backward pass.
    pub dlogits: Tensor,
}

/// Softmax + cross-entropy against integer labels.
///
/// `softmax_input_fmt` models the representation the last-layer Forward
/// GEMM output is stored in before the Softmax (Table 3's knob).
pub fn softmax_xent(
    logits: &Tensor,
    labels: &[usize],
    softmax_input_fmt: FloatFormat,
    loss_scale: f32,
) -> LossOut {
    assert_eq!(logits.ndim(), 2);
    let (n, c) = (logits.shape[0], logits.shape[1]);
    assert_eq!(labels.len(), n);

    let mut dlogits = Tensor::zeros(&[n, c]);
    let mut loss = 0f64;
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        debug_assert!(label < c);
        let row = &logits.data[i * c..(i + 1) * c];
        // Quantize the Softmax input representation (identity for FP32).
        let q: Vec<f32> = row
            .iter()
            .map(|&v| softmax_input_fmt.quantize(v, RoundMode::NearestEven))
            .collect();
        // Numerically-stable softmax in f32/f64.
        let max = q.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> = q.iter().map(|&v| ((v - max) as f64).exp()).collect();
        let z: f64 = exps.iter().sum();
        let p_label = exps[label] / z;
        loss -= p_label.max(1e-30).ln();
        // Total, first-max-wins argmax. NaN logits are reachable the moment
        // a run diverges (a sweep cell, an aggressive format), so the
        // comparison must not panic: NaN candidates never win, ties keep
        // the earliest index, and a row with no comparable value (all NaN)
        // yields no prediction and counts as incorrect.
        let mut pred: Option<usize> = None;
        let mut best = f32::NEG_INFINITY;
        for (j, &v) in q.iter().enumerate() {
            if !v.is_nan() && (pred.is_none() || v > best) {
                pred = Some(j);
                best = v;
            }
        }
        if pred == Some(label) {
            correct += 1;
        }
        let scale = loss_scale / n as f32;
        for j in 0..c {
            let p = (exps[j] / z) as f32;
            dlogits.data[i * c + j] = (p - if j == label { 1.0 } else { 0.0 }) * scale;
        }
    }
    LossOut {
        loss: loss / n as f64,
        correct,
        dlogits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_log_c() {
        let logits = Tensor::zeros(&[4, 10]);
        let out = softmax_xent(&logits, &[0, 1, 2, 3], FloatFormat::FP32, 1.0);
        assert!((out.loss - (10f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn gradient_sums_to_zero_per_row_and_matches_softmax() {
        let logits = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let out = softmax_xent(&logits, &[2], FloatFormat::FP32, 1.0);
        let row = &out.dlogits.data;
        assert!((row.iter().sum::<f32>()).abs() < 1e-6);
        // p = softmax([1,2,3]); d = p - onehot(2).
        let z: f64 = (1..=3).map(|i| (i as f64).exp()).sum();
        for j in 0..3 {
            let p = ((j + 1) as f64).exp() / z;
            let want = p - if j == 2 { 1.0 } else { 0.0 };
            assert!((row[j] as f64 - want).abs() < 1e-6, "j={j}");
        }
    }

    #[test]
    fn loss_scale_multiplies_gradient_only() {
        let logits = Tensor::from_vec(&[1, 2], vec![0.3, -0.7]);
        let a = softmax_xent(&logits, &[0], FloatFormat::FP32, 1.0);
        let b = softmax_xent(&logits, &[0], FloatFormat::FP32, 1000.0);
        assert_eq!(a.loss, b.loss);
        for (x, y) in a.dlogits.data.iter().zip(&b.dlogits.data) {
            assert!((y - x * 1000.0).abs() < 1e-3);
        }
    }

    #[test]
    fn gradient_check_vs_finite_difference() {
        let logits = Tensor::from_vec(&[2, 4], vec![0.5, -1.0, 2.0, 0.1, -0.3, 0.9, 0.0, 1.5]);
        let labels = [2usize, 1];
        let out = softmax_xent(&logits, &labels, FloatFormat::FP32, 1.0);
        let eps = 1e-3f32;
        for i in 0..8 {
            let mut lp = logits.clone();
            lp.data[i] += eps;
            let mut lm = logits.clone();
            lm.data[i] -= eps;
            let fp = softmax_xent(&lp, &labels, FloatFormat::FP32, 1.0).loss;
            let fm = softmax_xent(&lm, &labels, FloatFormat::FP32, 1.0).loss;
            let num = ((fp - fm) / (2.0 * eps as f64)) as f32;
            assert!(
                (num - out.dlogits.data[i]).abs() < 1e-3,
                "i={i}: num {num} vs {}",
                out.dlogits.data[i]
            );
        }
    }

    #[test]
    fn fp8_softmax_input_loses_fidelity() {
        // Table 3's mechanism: close logits become indistinguishable after
        // FP8 quantization of the Softmax input.
        let logits = Tensor::from_vec(&[1, 2], vec![4.0, 4.4]); // FP8 grid step at 4.0 is 1.0
        let fp32 = softmax_xent(&logits, &[1], FloatFormat::FP32, 1.0);
        let fp8 = softmax_xent(&logits, &[1], FloatFormat::FP8, 1.0);
        // FP8 rounds both to 4.0: the margin vanishes, loss becomes ln 2.
        assert!(fp32.loss < fp8.loss);
        assert!((fp8.loss - (2f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn nan_logits_do_not_panic_and_count_incorrect() {
        // Regression: the argmax used partial_cmp(..).unwrap() and panicked
        // on the first NaN logit (reachable whenever a sweep cell
        // diverges). Row 0 is all-NaN (no prediction → incorrect), row 1
        // mixes a NaN into an otherwise-winning row (NaN never wins),
        // row 2 is clean.
        let nan = f32::NAN;
        let logits = Tensor::from_vec(
            &[3, 3],
            vec![nan, nan, nan, 1.0, nan, 5.0, 0.0, 9.0, 1.0],
        );
        let out = softmax_xent(&logits, &[0, 2, 1], FloatFormat::FP32, 1.0);
        assert_eq!(out.correct, 2); // rows 1 and 2; the all-NaN row is wrong
    }

    #[test]
    fn argmax_tie_keeps_first_index() {
        // First-max-wins: a tied row predicts the earliest class, totally
        // ordered regardless of float comparison quirks (-inf rows
        // included).
        let ninf = f32::NEG_INFINITY;
        let logits = Tensor::from_vec(&[2, 3], vec![2.0, 2.0, 1.0, ninf, ninf, ninf]);
        let out = softmax_xent(&logits, &[0, 0], FloatFormat::FP32, 1.0);
        assert_eq!(out.correct, 2);
        let out = softmax_xent(&logits, &[1, 1], FloatFormat::FP32, 1.0);
        assert_eq!(out.correct, 0);
    }

    #[test]
    fn accuracy_counting() {
        let logits = Tensor::from_vec(&[3, 2], vec![2.0, 1.0, 0.0, 1.0, 5.0, -5.0]);
        let out = softmax_xent(&logits, &[0, 1, 1], FloatFormat::FP32, 1.0);
        assert_eq!(out.correct, 2);
    }
}
