//! Fully-connected layer with the three reduced-precision GEMMs of
//! Fig. 2(a).
//!
//! Data flow per training step (shapes row-major):
//!
//! ```text
//! Forward:   Y[N,out]  = Xq[N,in]  · Wqᵀ[in,out]   + b      (FP8·FP8 → FP16 acc)
//! Backward:  dX[N,in]  = dYq[N,out] · Wq[out,in]            (errors back)
//! Gradient:  dW[out,in] = dYqᵀ[out,N] · Xq[N,in]            (K = batch! §4.2)
//! ```
//!
//! Faithful to the paper's storage model: activations are quantized **once**
//! when produced (stored in FP8) and that same stored value feeds both the
//! Forward and Gradient GEMMs; likewise the error tensor is quantized once
//! and feeds both Backward and Gradient GEMMs. Weights live in the master
//! format (FP16 under the paper's scheme); their FP8 GEMM operands come
//! from the version-keyed **quantized pack cache** on the weight tensor
//! (`Tensor::quantized`/`quantized_t`, `docs/perf.md`) — quantized once per
//! weight update and shared by the Forward and Backward GEMMs, with no
//! per-GEMM clone. Table 2 baseline schemes (custom quantizers) keep the
//! explicit clone-and-quantize dataflow.

use super::quant::{GemmRole, LayerPos, QuantCtx};
use super::{Layer, Param};
use crate::numerics::{RoundMode, Xoshiro256};
use crate::tensor::{init, Tensor};

pub struct Linear {
    pub w: Param, // [out, in]
    pub b: Option<Param>,
    pub pos: LayerPos,
    layer_id: u64,
    in_dim: usize,
    out_dim: usize,
    // caches for backward: the stored activation, and (baseline schemes
    // only) the scheme-quantized weight copy.
    x_q: Option<Tensor>,
    w_q: Option<Tensor>,
}

/// FNV-1a hash of a layer name — the stable per-layer id that seeds
/// stochastic rounding streams.
pub(crate) fn layer_hash(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl Linear {
    pub fn new(name: &str, in_dim: usize, out_dim: usize, pos: LayerPos, rng: &mut Xoshiro256) -> Self {
        let w = init::kaiming_normal(&[out_dim, in_dim], in_dim, rng);
        Self {
            w: Param::new(format!("{name}.w"), w, true),
            b: Some(Param::new(format!("{name}.b"), Tensor::zeros(&[out_dim]), false)),
            pos,
            layer_id: layer_hash(name),
            in_dim,
            out_dim,
            x_q: None,
            w_q: None,
        }
    }

    pub fn no_bias(mut self) -> Self {
        self.b = None;
        self
    }
}

impl Layer for Linear {
    fn forward(&mut self, x: Tensor, ctx: &QuantCtx) -> Tensor {
        assert_eq!(x.ndim(), 2, "linear expects [N, in]");
        assert_eq!(x.shape[1], self.in_dim);
        let _tel = crate::telemetry::layer_scope(self.w.name.trim_end_matches(".w"));
        let p = ctx.policy;

        // Quantize the stored activation once (nearest — conversions in
        // the paper's data path use nearest; SR is reserved for updates).
        let mut x_q = x;
        p.quantize_act(&mut x_q.data, GemmRole::Forward, self.pos);

        let prec = p.gemm_for(GemmRole::Forward, self.pos);
        let seed = ctx.gemm_seed(self.layer_id, GemmRole::Forward);
        // W is stored [out, in] — exactly the packed-Bᵀ layout the GEMM
        // consumes for Y = X·Wᵀ, so the forward pass performs no transpose;
        // the quantized operand comes straight from the weight tensor's
        // version-keyed pack cache (no clone, quantized once per update).
        let mut y = match p.plain_weight_fmt(GemmRole::Forward, self.pos) {
            // Identity formats (fp32 policies): the stored [out, in] data
            // IS the packed operand — no copy, no cache entry.
            Some(fmt) if fmt.is_identity() => {
                x_q.matmul_packed(&self.w.value.data, self.out_dim, &prec, seed)
            }
            Some(fmt) => {
                let w_pack = self.w.value.quantized(fmt, RoundMode::NearestEven);
                x_q.matmul_packed(&w_pack, self.out_dim, &prec, seed)
            }
            None => {
                // Baseline schemes: explicit clone + custom quantizer.
                let mut w_q = self.w.value.clone();
                p.quantize_weight(&mut w_q.data, GemmRole::Forward, self.pos);
                let y = x_q.matmul_t(&w_q, &prec, seed);
                if ctx.train {
                    self.w_q = Some(w_q);
                }
                y
            }
        };
        if let Some(b) = &self.b {
            y.add_row(&b.value.data);
        }
        if ctx.train {
            self.x_q = Some(x_q);
        }
        y
    }

    fn backward(&mut self, dy: Tensor, ctx: &QuantCtx) -> Tensor {
        let _tel = crate::telemetry::layer_scope(self.w.name.trim_end_matches(".w"));
        let p = ctx.policy;
        let x_q = self.x_q.take().expect("backward before forward");
        let n = dy.shape[0];
        assert_eq!(dy.shape, vec![n, self.out_dim]);

        // Bias gradient in full precision (tiny AXPY, not a GEMM).
        if let Some(b) = &mut self.b {
            for (g, v) in b.grad.data.iter_mut().zip(dy.sum_rows()) {
                *g += v;
            }
        }

        // Error tensor stored once in the error format.
        let mut err = dy;
        p.quantize_err(
            &mut err.data,
            GemmRole::Backward,
            self.pos,
            ctx.gemm_seed(self.layer_id, GemmRole::Backward) ^ 0xE44,
        );

        // Gradient GEMM: dW = errᵀ · Xq, K = batch dimension. The
        // transposed error operand and the gradient are step-local
        // temporaries → scratch arena.
        let prec_g = p.gemm_for(GemmRole::Gradient, self.pos);
        let err_t = err.t_pooled();
        let dw = err_t.matmul(&x_q, &prec_g, ctx.gemm_seed(self.layer_id, GemmRole::Gradient));
        err_t.recycle();
        self.w.grad.add_assign(&dw);
        dw.recycle();
        x_q.recycle();

        // Backward GEMM: dX = err · Wq. The weight operand is the same
        // stored (Forward-format) quantized copy the forward pass used —
        // served from the cache in its transposed packed form.
        let prec_b = p.gemm_for(GemmRole::Backward, self.pos);
        let seed_b = ctx.gemm_seed(self.layer_id, GemmRole::Backward);
        let dx = match p.plain_weight_fmt(GemmRole::Forward, self.pos) {
            // Identity formats: the plain transpose cache suffices.
            Some(fmt) if fmt.is_identity() => {
                let w_pack = self.w.value.packed_t();
                err.matmul_packed(&w_pack, self.in_dim, &prec_b, seed_b)
            }
            Some(fmt) => {
                let w_pack = self.w.value.quantized_t(fmt, RoundMode::NearestEven);
                err.matmul_packed(&w_pack, self.in_dim, &prec_b, seed_b)
            }
            None => {
                let w_q = self.w_q.take().expect("backward before forward");
                let dx = err.matmul(&w_q, &prec_b, seed_b);
                w_q.recycle();
                dx
            }
        };
        err.recycle();
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.w);
        if let Some(b) = &mut self.b {
            f(b);
        }
    }

    fn name(&self) -> String {
        self.w.name.trim_end_matches(".w").to_string()
    }

    fn macs_per_example(&self) -> u64 {
        (self.in_dim * self.out_dim) as u64
    }

    fn invalidate_backward_state(&mut self) {
        // Eval forwards don't refresh `x_q`/`w_q`; a stale copy from the
        // last training batch would satisfy `backward`'s `take()` and feed
        // the Gradient GEMM the wrong activations whenever batch shapes
        // coincide. Recycle rather than drop — these are arena tensors.
        if let Some(t) = self.x_q.take() {
            t.recycle();
        }
        if let Some(t) = self.w_q.take() {
            t.recycle();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::PrecisionPolicy;
    use crate::testkit::assert_slices_close;

    fn grad_check_linear(policy: &PrecisionPolicy) {
        // Finite-difference gradient check under the FP32 policy.
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut l = Linear::new("fc", 5, 3, LayerPos::Middle, &mut rng);
        let ctx = QuantCtx::new(policy, 0, true);
        let x = Tensor::from_vec(&[2, 5], (0..10).map(|i| 0.1 * i as f32 - 0.4).collect());
        let dy = Tensor::from_vec(&[2, 3], (0..6).map(|i| 0.3 - 0.1 * i as f32).collect());

        let _y = l.forward(x.clone(), &ctx);
        let dx = l.backward(dy.clone(), &ctx);

        // loss = <Y, dy>; check d loss / d x numerically.
        let eps = 1e-3f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let mut lp = Linear::new("fc", 5, 3, LayerPos::Middle, &mut Xoshiro256::seed_from_u64(1));
            let mut lm = Linear::new("fc", 5, 3, LayerPos::Middle, &mut Xoshiro256::seed_from_u64(1));
            let yp = lp.forward(xp, &ctx);
            let ym = lm.forward(xm, &ctx);
            let fp: f32 = yp.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let fm: f32 = ym.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 1e-2,
                "dx[{i}]: numeric {num} vs analytic {}",
                dx.data[i]
            );
        }
    }

    #[test]
    fn fp32_gradcheck() {
        grad_check_linear(&PrecisionPolicy::fp32());
    }

    #[test]
    fn weight_grad_matches_outer_product() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut l = Linear::new("fc", 3, 2, LayerPos::Middle, &mut rng);
        let x = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]);
        let dy = Tensor::from_vec(&[1, 2], vec![0.5, -1.0]);
        l.forward(x, &ctx);
        l.backward(dy, &ctx);
        // dW[o,i] = dy[o]·x[i]
        assert_slices_close(
            &l.w.grad.data,
            &[0.5, 1.0, 1.5, -1.0, -2.0, -3.0],
            1e-6,
            1e-6,
        );
        assert_slices_close(&l.b.as_ref().unwrap().grad.data, &[0.5, -1.0], 1e-6, 1e-6);
    }

    #[test]
    fn grads_accumulate_across_calls() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut l = Linear::new("fc", 2, 2, LayerPos::Middle, &mut rng);
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let dy = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        l.forward(x.clone(), &ctx);
        l.backward(dy.clone(), &ctx);
        let g1 = l.w.grad.data.clone();
        l.forward(x, &ctx);
        l.backward(dy, &ctx);
        for (a, b) in l.w.grad.data.iter().zip(&g1) {
            assert!((a - 2.0 * b).abs() < 1e-6);
        }
    }

    #[test]
    fn cached_weight_pack_dataflow_matches_explicit_clone() {
        // The cached quantized-pack dataflow vs the pre-refactor explicit
        // clone-and-quantize dataflow — outputs, dX and dW bit-identical,
        // across two consecutive steps (the second step exercises
        // post-mutation cache rebuilds after the direct weight write).
        for policy in [PrecisionPolicy::fp8_paper(), PrecisionPolicy::fp32()] {
            let mut rng = Xoshiro256::seed_from_u64(12);
            let mut l = Linear::new("fc", 6, 4, LayerPos::Middle, &mut rng);
            let id = layer_hash("fc");
            for step in 0..2u64 {
                let ctx = QuantCtx::new(&policy, step, true);
                let x = Tensor::from_vec(
                    &[3, 6],
                    (0..18).map(|i| (i as f32 - 9.0) * 0.173).collect(),
                );
                let dy = Tensor::from_vec(
                    &[3, 4],
                    (0..12).map(|i| ((i * 5 % 7) as f32 - 3.0) * 0.31).collect(),
                );
                l.visit_params(&mut |p| p.zero_grad());
                let y = l.forward(x.clone(), &ctx);
                let dx = l.backward(dy.clone(), &ctx);

                // --- the explicit (pre-refactor) dataflow ---
                let p = &policy;
                let mut x_q = x;
                p.quantize_act(&mut x_q.data, GemmRole::Forward, LayerPos::Middle);
                let mut w_q = l.w.value.clone();
                p.quantize_weight(&mut w_q.data, GemmRole::Forward, LayerPos::Middle);
                let prec = p.gemm_for(GemmRole::Forward, LayerPos::Middle);
                let mut y_ref = x_q.matmul_t(&w_q, &prec, ctx.gemm_seed(id, GemmRole::Forward));
                y_ref.add_row(&l.b.as_ref().unwrap().value.data);
                assert_eq!(y, y_ref, "{} step {step} forward", policy.name);

                let mut err = dy;
                p.quantize_err(
                    &mut err.data,
                    GemmRole::Backward,
                    LayerPos::Middle,
                    ctx.gemm_seed(id, GemmRole::Backward) ^ 0xE44,
                );
                let prec_g = p.gemm_for(GemmRole::Gradient, LayerPos::Middle);
                let dw_ref = err
                    .t()
                    .matmul(&x_q, &prec_g, ctx.gemm_seed(id, GemmRole::Gradient));
                assert_eq!(l.w.grad, dw_ref, "{} step {step} dW", policy.name);
                let prec_b = p.gemm_for(GemmRole::Backward, LayerPos::Middle);
                let dx_ref = err.matmul(&w_q, &prec_b, ctx.gemm_seed(id, GemmRole::Backward));
                assert_eq!(dx, dx_ref, "{} step {step} dX", policy.name);

                // Mutate the master weight between steps (as the update
                // AXPY would) so step 1 must rebuild every cached pack.
                l.w.value.data[0] += 0.5;
                l.w.value.mark_mutated();
            }
        }
    }

    #[test]
    fn fp8_forward_quantizes_operands() {
        // With the paper policy, a middle layer's output must be built from
        // FP8-quantized operands: feed values that change under FP8.
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut l = Linear::new("fc", 1, 1, LayerPos::Middle, &mut rng).no_bias();
        l.w.value.data[0] = 1.1; // FP8 rounds to 1.0
        let x = Tensor::from_vec(&[1, 1], vec![1.1]);
        let y = l.forward(x, &ctx);
        assert_eq!(y.data[0], 1.0); // 1.0 (q(1.1)) · 1.0 (q(1.1))
    }

    #[test]
    fn first_layer_keeps_fp16_input() {
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut l = Linear::new("fc", 1, 1, LayerPos::First, &mut rng).no_bias();
        l.w.value.data[0] = 1.0;
        // 133.0 is exactly representable in FP16 (1,6,9) but rounds to 128
        // in FP8 (1,5,2).
        let y = l.forward(Tensor::from_vec(&[1, 1], vec![133.0]), &ctx);
        assert_eq!(y.data[0], 133.0);
        let mut m = Linear::new("fc", 1, 1, LayerPos::Middle, &mut rng).no_bias();
        m.w.value.data[0] = 1.0;
        let y = m.forward(Tensor::from_vec(&[1, 1], vec![133.0]), &ctx);
        assert_eq!(y.data[0], 128.0);
    }

    #[test]
    fn eval_mode_keeps_no_cache() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, false);
        let mut rng = Xoshiro256::seed_from_u64(6);
        let mut l = Linear::new("fc", 2, 2, LayerPos::Middle, &mut rng);
        l.forward(Tensor::zeros(&[1, 2]), &ctx);
        assert!(l.x_q.is_none());
    }

    #[test]
    fn invalidation_drops_the_stale_train_cache() {
        let policy = PrecisionPolicy::fp32();
        let train = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut l = Linear::new("fc", 2, 2, LayerPos::Middle, &mut rng);
        l.forward(Tensor::zeros(&[1, 2]), &train);
        assert!(l.x_q.is_some(), "train forward must cache the activation");
        l.invalidate_backward_state();
        assert!(l.x_q.is_none(), "invalidation must drop the cache");
    }
}
