//! The native neural-network engine.
//!
//! Layers implement hand-written forward/backward passes whose every GEMM
//! is routed through the reduced-precision emulation in [`crate::numerics`]
//! according to a [`quant::PrecisionPolicy`] — this is the software
//! equivalent of the paper's in-house GPU emulation framework [7], and the
//! machinery every experiment in `experiments/` runs on.
//!
//! Topology is explicit (no autograd): [`Sequential`] chains layers,
//! [`block::Residual`] implements ResNet skip connections, and
//! architectures are described as data by [`spec::ModelSpec`] — a
//! declarative, parseable layer list compiled onto these layers with
//! spec-driven shape inference. The paper's six benchmark networks are
//! named preset specs (hand-built reference builders live under
//! [`models`] for the bit-exactness bridge tests).

pub mod act;
pub mod baselines;
pub mod block;
pub mod conv;
pub mod linear;
pub mod loss;
pub mod models;
pub mod norm;
pub mod pool;
pub mod quant;
pub mod spec;

pub use block::Residual;
pub use conv::Conv2d;
pub use linear::Linear;
pub use loss::softmax_xent;
pub use quant::{GemmRole, LayerPos, PrecisionPolicy, QuantCtx};
pub use spec::{LoweredUnit, ModelSpec, SpecBuilder, SpecError};

use crate::state::{self, StateDict, StateError, StateMap};
use crate::tensor::Tensor;

/// One learnable parameter tensor with its gradient accumulator.
#[derive(Clone, Debug)]
pub struct Param {
    /// Dotted path, e.g. `conv1.w` — stable across runs, used by
    /// checkpoints and the experiment harnesses.
    pub name: String,
    pub value: Tensor,
    pub grad: Tensor,
    /// Whether L2 regularization (weight decay) applies — `true` for
    /// weights, `false` for biases and BN affine parameters (standard
    /// practice, and what keeps the BN path out of Fig. 2(b)'s L2 fold).
    pub decay: bool,
}

impl Param {
    pub fn new(name: impl Into<String>, value: Tensor, decay: bool) -> Self {
        let grad = Tensor::zeros(&value.shape);
        Self {
            name: name.into(),
            value,
            grad,
            decay,
        }
    }

    pub fn zero_grad(&mut self) {
        self.grad.data.fill(0.0);
    }
}

/// A differentiable layer with hand-written backward.
///
/// Contract: `backward` must be called after `forward` with the same batch
/// (layers cache whatever activations their backward needs), accumulates
/// into `Param::grad`, and returns `dL/dx`.
pub trait Layer: Send {
    fn forward(&mut self, x: Tensor, ctx: &QuantCtx) -> Tensor;
    fn backward(&mut self, dy: Tensor, ctx: &QuantCtx) -> Tensor;

    /// Visit every learnable parameter (used by optimizers, checkpoints,
    /// and the parameter-count reports of Table 1).
    fn visit_params(&mut self, _f: &mut dyn FnMut(&mut Param)) {}

    fn name(&self) -> String;

    /// Multiply–accumulate count per example (for the FLOP budgets quoted
    /// in §4.1 and the hardware model of Fig. 7).
    fn macs_per_example(&self) -> u64 {
        0
    }

    /// Downcast hook (used by experiment harnesses that instrument
    /// specific layers, e.g. Fig. 6's Gradient-GEMM operand capture).
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }

    /// Drop any cached backward state from a previous *train-mode*
    /// forward. Layers only refresh their activation caches when
    /// `ctx.train` is set, so an eval-mode forward would otherwise leave
    /// caches from the last training batch in place — and a subsequent
    /// `backward` would silently mix batches whenever the shapes happen to
    /// line up. [`Sequential::forward`] calls this on every child after an
    /// eval-mode forward, so the hazard is closed in one place for every
    /// layer kind; stateless layers keep the default no-op.
    fn invalidate_backward_state(&mut self) {}

    /// Checkpoint hook for layer state that is **not** a [`Param`] —
    /// parameters are handled generically through
    /// [`visit_params`](Self::visit_params) by [`save_layer_state`].
    /// `BatchNorm` overrides this for its running statistics; containers
    /// (`Sequential`, `Residual`) override to recurse.
    fn save_extra_state(&mut self, _prefix: &str, _out: &mut StateMap) {}

    /// Restore counterpart of [`save_extra_state`](Self::save_extra_state).
    fn load_extra_state(&mut self, _prefix: &str, _src: &StateMap) -> Result<(), StateError> {
        Ok(())
    }
}

/// Serialize a layer tree: every [`Param`] (dotted names are globally
/// unique within a model) plus each layer's extra state, under `prefix`.
/// Gradient accumulators are *not* saved — checkpoints are taken at step
/// boundaries where the optimizer has just zeroed them.
pub fn save_layer_state(layer: &mut dyn Layer, prefix: &str, out: &mut StateMap) {
    layer.visit_params(&mut |p| {
        out.put_tensor(&state::key(prefix, &p.name), &p.value.shape, &p.value.data);
    });
    layer.save_extra_state(prefix, out);
}

/// Strict restore counterpart of [`save_layer_state`]: every parameter and
/// every piece of extra state must be present with matching shape.
pub fn load_layer_state(
    layer: &mut dyn Layer,
    prefix: &str,
    src: &StateMap,
) -> Result<(), StateError> {
    let mut first_err: Option<StateError> = None;
    layer.visit_params(&mut |p| {
        if first_err.is_some() {
            return;
        }
        let k = state::key(prefix, &p.name);
        match src.copy_tensor_into(&k, &p.value.shape, &mut p.value.data) {
            Ok(()) => p.value.mark_mutated(),
            Err(e) => first_err = Some(e),
        }
    });
    if let Some(e) = first_err {
        return Err(e);
    }
    layer.load_extra_state(prefix, src)
}

/// Every concrete layer (and the model containers) implements [`StateDict`]
/// through the generic param walk + extra-state hooks.
macro_rules! impl_layer_state_dict {
    ($($t:ty),+ $(,)?) => {$(
        impl StateDict for $t {
            fn save_state(&mut self, prefix: &str, out: &mut StateMap) {
                save_layer_state(self, prefix, out);
            }

            fn load_state(&mut self, prefix: &str, src: &StateMap) -> Result<(), StateError> {
                load_layer_state(self, prefix, src)
            }
        }
    )+};
}

impl_layer_state_dict!(
    Sequential,
    Flatten,
    block::Residual,
    linear::Linear,
    conv::Conv2d,
    norm::BatchNorm,
    act::Relu,
    pool::MaxPool2d,
    pool::GlobalAvgPool,
);

/// A straight chain of layers.
pub struct Sequential {
    pub layers: Vec<Box<dyn Layer>>,
}

impl Sequential {
    pub fn new(layers: Vec<Box<dyn Layer>>) -> Self {
        Self { layers }
    }

    /// Total learnable parameter count.
    pub fn num_params(&mut self) -> usize {
        let mut n = 0;
        self.visit_params(&mut |p| n += p.value.len());
        n
    }

    /// Model size in megabytes at `bits` per parameter (Table 1 quotes
    /// weight memory at the representation width).
    pub fn size_mb(&mut self, bits: u32) -> f64 {
        self.num_params() as f64 * bits as f64 / 8.0 / 1e6
    }

    pub fn zero_grads(&mut self) {
        self.visit_params(&mut Param::zero_grad);
    }
}

impl Layer for Sequential {
    fn forward(&mut self, mut x: Tensor, ctx: &QuantCtx) -> Tensor {
        for l in &mut self.layers {
            x = l.forward(x, ctx);
            if !ctx.train {
                // Eval forwards do not refresh backward caches; invalidate
                // whatever a previous training forward left behind so a
                // mispaired backward fails loudly instead of mixing
                // batches (the eval-then-backward hazard — see the trait
                // method's docs).
                l.invalidate_backward_state();
            }
        }
        x
    }

    fn backward(&mut self, mut dy: Tensor, ctx: &QuantCtx) -> Tensor {
        for l in self.layers.iter_mut().rev() {
            dy = l.backward(dy, ctx);
        }
        dy
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        for l in &mut self.layers {
            l.visit_params(f);
        }
    }

    fn name(&self) -> String {
        "sequential".into()
    }

    fn macs_per_example(&self) -> u64 {
        self.layers.iter().map(|l| l.macs_per_example()).sum()
    }

    fn save_extra_state(&mut self, prefix: &str, out: &mut StateMap) {
        for l in &mut self.layers {
            l.save_extra_state(prefix, out);
        }
    }

    fn load_extra_state(&mut self, prefix: &str, src: &StateMap) -> Result<(), StateError> {
        for l in &mut self.layers {
            l.load_extra_state(prefix, src)?;
        }
        Ok(())
    }

    fn invalidate_backward_state(&mut self) {
        // Covers direct-call uses (a Sequential nested inside another
        // container); the forward walk above already invalidates children
        // during its own eval forwards.
        for l in &mut self.layers {
            l.invalidate_backward_state();
        }
    }
}

/// Reshape NCHW feature maps to `[N, C·H·W]` rows for the FC head.
pub struct Flatten {
    in_shape: Vec<usize>,
}

impl Flatten {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Self {
        Self { in_shape: vec![] }
    }
}

impl Layer for Flatten {
    fn forward(&mut self, x: Tensor, _ctx: &QuantCtx) -> Tensor {
        self.in_shape = x.shape.clone();
        let n = x.shape[0];
        let rest: usize = x.shape[1..].iter().product();
        x.reshape(&[n, rest])
    }

    fn backward(&mut self, dy: Tensor, _ctx: &QuantCtx) -> Tensor {
        assert!(
            !self.in_shape.is_empty(),
            "flatten backward without a matching train-mode forward"
        );
        dy.reshape(&self.in_shape.clone())
    }

    fn name(&self) -> String {
        "flatten".into()
    }

    fn invalidate_backward_state(&mut self) {
        self.in_shape.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flatten_roundtrips_shape() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut f = Flatten::new();
        let x = Tensor::zeros(&[2, 3, 4, 4]);
        let y = f.forward(x, &ctx);
        assert_eq!(y.shape, vec![2, 48]);
        let dx = f.backward(y, &ctx);
        assert_eq!(dx.shape, vec![2, 3, 4, 4]);
    }

    /// The eval-then-backward hazard, exercised for **every** stateful
    /// layer kind through the one place that now owns the invalidation
    /// (`Sequential::forward`): a train forward plants caches, an eval
    /// forward must drop them, and the mispaired backward has to fail
    /// loudly rather than silently reuse the previous training batch.
    #[test]
    fn eval_forward_invalidates_every_layer_kind() {
        use crate::numerics::Xoshiro256;
        use crate::tensor::Conv2dGeom;
        use std::panic::{catch_unwind, AssertUnwindSafe};

        let policy = PrecisionPolicy::fp32();
        let train = QuantCtx::new(&policy, 0, true);
        let eval = QuantCtx::new(&policy, 0, false);

        let geom = Conv2dGeom {
            in_c: 2,
            in_h: 4,
            in_w: 4,
            k: 3,
            stride: 1,
            pad: 1,
        };
        let mut rng = Xoshiro256::seed_from_u64(7);
        let residual = Residual::new(Sequential::new(vec![Box::new(act::Relu::new())]), None);

        // (kind, model, input shape, dy shape) — one row per layer kind
        // that caches backward state.
        let cases: Vec<(&str, Sequential, Vec<usize>, Vec<usize>)> = vec![
            (
                "linear",
                Sequential::new(vec![Box::new(Linear::new(
                    "fc",
                    8,
                    4,
                    LayerPos::Middle,
                    &mut rng,
                ))]),
                vec![2, 8],
                vec![2, 4],
            ),
            (
                "conv2d",
                Sequential::new(vec![Box::new(Conv2d::new(
                    "c",
                    geom,
                    3,
                    LayerPos::Middle,
                    true,
                    &mut rng,
                ))]),
                vec![2, 2, 4, 4],
                vec![2, 3, 4, 4],
            ),
            (
                "relu",
                Sequential::new(vec![Box::new(act::Relu::new())]),
                vec![2, 8],
                vec![2, 8],
            ),
            (
                "batchnorm",
                Sequential::new(vec![Box::new(norm::BatchNorm::new_2d("bn", 2))]),
                vec![2, 2, 4, 4],
                vec![2, 2, 4, 4],
            ),
            (
                "maxpool",
                Sequential::new(vec![Box::new(pool::MaxPool2d::new(2, 2))]),
                vec![2, 2, 4, 4],
                vec![2, 2, 2, 2],
            ),
            (
                "gap",
                Sequential::new(vec![Box::new(pool::GlobalAvgPool::new())]),
                vec![2, 2, 4, 4],
                vec![2, 2],
            ),
            (
                "flatten",
                Sequential::new(vec![Box::new(Flatten::new())]),
                vec![2, 2, 4, 4],
                vec![2, 32],
            ),
            (
                "residual",
                Sequential::new(vec![Box::new(residual)]),
                vec![2, 8],
                vec![2, 8],
            ),
        ];

        for (kind, mut model, in_shape, dy_shape) in cases {
            // Sanity: a properly paired train forward/backward works.
            model.forward(Tensor::zeros(&in_shape), &train);
            model.backward(Tensor::zeros(&dy_shape), &train);
            // Plant caches, then run an eval forward over the same shapes —
            // the most dangerous variant, since no shape assert can save us
            // if the stale caches survive.
            model.forward(Tensor::zeros(&in_shape), &train);
            model.forward(Tensor::zeros(&in_shape), &eval);
            let r = catch_unwind(AssertUnwindSafe(|| {
                model.backward(Tensor::zeros(&dy_shape), &eval);
            }));
            assert!(
                r.is_err(),
                "{kind}: backward after an eval forward must panic, not \
                 reuse the previous training batch's caches"
            );
        }
    }

    #[test]
    fn param_zero_grad() {
        let mut p = Param::new("w", Tensor::full(&[2, 2], 1.0), true);
        p.grad.data.fill(3.0);
        p.zero_grad();
        assert!(p.grad.data.iter().all(|&v| v == 0.0));
        assert!(p.decay);
    }
}
