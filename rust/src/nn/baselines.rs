//! Baseline reduced-precision training schemes compared in Table 2.
//!
//! | scheme | W | x | dW | dx | acc |
//! |--------|---|---|----|----|-----|
//! | DoReFa-Net [23] | 1 | 2 | 32 | 6 | 32 |
//! | WAGE [20]       | 2 | 8 | 8  | 8 | 32 |
//! | DFP [4]         | 16 | 16 | 16 | 16 | 32 |
//! | MPT [16]        | 16 | 16 | 16 | 16 | 32 |
//! | FP8 (ours)      | 8 | 8 | 8  | 8 | 16 |
//!
//! Each scheme is a set of tensor quantizers plugged into the same layer
//! machinery the FP8 policy uses, so the Table 2 comparison trains the same
//! model with identical data/seed and only the quantization differs.
//! DoReFa and WAGE quantize to fixed-point grids (values exactly
//! representable in f32, so the f32-carrier GEMM is exact); DFP uses a
//! per-tensor shared exponent with a 16-bit mantissa; MPT is IEEE half —
//! all with FP32 accumulation, which is the contrast to our FP16 chunked
//! accumulation.

use crate::numerics::rng::RoundBits;
use crate::numerics::{FloatFormat, RoundMode, Xoshiro256};

/// One Table 2 comparison scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineScheme {
    /// DoReFa-Net: 1-bit weights, 2-bit activations, 6-bit (stochastically
    /// quantized) errors, FP32 weight gradients.
    DoReFa,
    /// WAGE: 2-bit weights, 8-bit activations, 8-bit errors & gradients
    /// (shift-based fixed point).
    Wage,
    /// Dynamic fixed point: 16-bit mantissa, per-tensor shared exponent.
    Dfp16,
    /// Mixed-precision training: IEEE half (1,5,10) everywhere, FP32 acc.
    MptFp16,
}

impl BaselineScheme {
    pub fn id(self) -> &'static str {
        match self {
            BaselineScheme::DoReFa => "dorefa",
            BaselineScheme::Wage => "wage",
            BaselineScheme::Dfp16 => "dfp16",
            BaselineScheme::MptFp16 => "mpt_fp16",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "dorefa" => BaselineScheme::DoReFa,
            "wage" => BaselineScheme::Wage,
            "dfp16" => BaselineScheme::Dfp16,
            "mpt_fp16" | "mpt" => BaselineScheme::MptFp16,
            _ => return None,
        })
    }

    /// Quantize a weight tensor in place.
    pub fn quantize_weight(self, xs: &mut [f32]) {
        match self {
            BaselineScheme::DoReFa => dorefa_weight_1bit(xs),
            BaselineScheme::Wage => wage_weight_2bit(xs),
            BaselineScheme::Dfp16 => dfp_quantize(xs, 16),
            BaselineScheme::MptFp16 => {
                FloatFormat::IEEE_HALF.quantize_slice(xs, RoundMode::NearestEven)
            }
        }
    }

    /// Quantize an activation tensor in place.
    pub fn quantize_act(self, xs: &mut [f32]) {
        match self {
            BaselineScheme::DoReFa => dorefa_act(xs, 2),
            BaselineScheme::Wage => fixed_point_uniform(xs, 8),
            BaselineScheme::Dfp16 => dfp_quantize(xs, 16),
            BaselineScheme::MptFp16 => {
                FloatFormat::IEEE_HALF.quantize_slice(xs, RoundMode::NearestEven)
            }
        }
    }

    /// Quantize a back-propagated error tensor in place (`seed` feeds the
    /// stochastic gradient quantizers of DoReFa/WAGE).
    pub fn quantize_err(self, xs: &mut [f32], seed: u64) {
        match self {
            BaselineScheme::DoReFa => {
                let mut rng = Xoshiro256::seed_from_u64(seed);
                dorefa_grad(xs, 6, &mut rng);
            }
            BaselineScheme::Wage => {
                let mut rng = Xoshiro256::seed_from_u64(seed);
                wage_error(xs, 8, &mut rng);
            }
            BaselineScheme::Dfp16 => dfp_quantize(xs, 16),
            BaselineScheme::MptFp16 => {
                FloatFormat::IEEE_HALF.quantize_slice(xs, RoundMode::NearestEven)
            }
        }
    }
}

/// DoReFa 1-bit weights: `w_q = sign(w) · E[|w|]` (scaled binarization).
pub fn dorefa_weight_1bit(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let mean_abs = xs.iter().map(|v| v.abs() as f64).sum::<f64>() / xs.len() as f64;
    let s = mean_abs as f32;
    for v in xs.iter_mut() {
        *v = if *v >= 0.0 { s } else { -s };
    }
}

/// DoReFa k-bit activations: clip to [0,1], then uniform k-bit grid
/// `round(x·(2^k−1))/(2^k−1)`.
pub fn dorefa_act(xs: &mut [f32], k: u32) {
    let levels = ((1u32 << k) - 1) as f32;
    for v in xs.iter_mut() {
        let c = v.clamp(0.0, 1.0);
        *v = (c * levels).round() / levels;
    }
}

/// DoReFa k-bit gradient quantization (Eq. 12 of [23]): scale by
/// 2·max|g|, add uniform noise, quantize to k bits, rescale.
pub fn dorefa_grad<R: RoundBits>(xs: &mut [f32], k: u32, rng: &mut R) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().fold(0f32, |m, v| m.max(v.abs()));
    if max == 0.0 {
        return;
    }
    let levels = ((1u32 << k) - 1) as f32;
    for v in xs.iter_mut() {
        // x ∈ [0,1]; noise σ ∈ [−0.5,0.5]/levels.
        let x = *v / (2.0 * max) + 0.5;
        let noise = (rng.next_bits() as f32 / u32::MAX as f32 - 0.5) / levels;
        let q = ((x + noise).clamp(0.0, 1.0) * levels).round() / levels;
        *v = 2.0 * max * (q - 0.5);
    }
}

/// WAGE 2-bit weights: ternarize onto {−1, 0, +1}·σ with σ the layer scale
/// (shift-quantized max). WAGE stores weights in [−1,1] with width 2.
pub fn wage_weight_2bit(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().fold(0f32, |m, v| m.max(v.abs()));
    if max == 0.0 {
        return;
    }
    let sigma = pow2_ceil(max);
    let step = sigma / 2.0; // 2-bit: levels at −σ, −σ/2 … σ (uniform 4-level)
    for v in xs.iter_mut() {
        *v = (*v / step).round().clamp(-2.0, 2.0) * step;
    }
}

/// WAGE 8-bit error quantization: shift-scale by the max magnitude, then
/// stochastic uniform quantization to k bits.
pub fn wage_error<R: RoundBits>(xs: &mut [f32], k: u32, rng: &mut R) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().fold(0f32, |m, v| m.max(v.abs()));
    if max == 0.0 {
        return;
    }
    let scale = pow2_ceil(max);
    let levels = ((1u32 << (k - 1)) - 1) as f32; // signed grid
    for v in xs.iter_mut() {
        let x = (*v / scale * levels).clamp(-levels, levels);
        let floor = x.floor();
        let frac = x - floor;
        let up = (rng.next_bits() as f64 / (u32::MAX as f64 + 1.0)) < frac as f64;
        *v = (floor + if up { 1.0 } else { 0.0 }) / levels * scale;
    }
}

/// Uniform signed fixed-point quantization to k bits on [−max, max]
/// (nearest) — WAGE's activation grid.
pub fn fixed_point_uniform(xs: &mut [f32], k: u32) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().fold(0f32, |m, v| m.max(v.abs()));
    if max == 0.0 {
        return;
    }
    let scale = pow2_ceil(max);
    let levels = ((1u32 << (k - 1)) - 1) as f32;
    for v in xs.iter_mut() {
        *v = (*v / scale * levels).round().clamp(-levels, levels) / levels * scale;
    }
}

/// DFP / Flexpoint: one shared exponent per tensor (set by the max
/// magnitude), values stored as `mant_bits`-bit signed mantissas.
pub fn dfp_quantize(xs: &mut [f32], mant_bits: u32) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().fold(0f32, |m, v| m.max(v.abs()));
    if max == 0.0 {
        return;
    }
    // Shared exponent e: smallest power of two ≥ max; mantissa grid has
    // 2^(mant_bits−1)−1 positive steps.
    let scale = pow2_ceil(max);
    let levels = ((1u64 << (mant_bits - 1)) - 1) as f32;
    for v in xs.iter_mut() {
        *v = (*v / scale * levels).round().clamp(-levels, levels) / levels * scale;
    }
}

/// Smallest power of two ≥ |x| (the "shared exponent" shift).
fn pow2_ceil(x: f32) -> f32 {
    debug_assert!(x > 0.0);
    2f32.powi(x.log2().ceil() as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dorefa_weights_binarize_to_mean_abs() {
        let mut xs = vec![0.5, -1.5, 1.0, -1.0];
        dorefa_weight_1bit(&mut xs);
        let s = (0.5 + 1.5 + 1.0 + 1.0) / 4.0;
        assert_eq!(xs, vec![s, -s, s, -s]);
    }

    #[test]
    fn dorefa_act_two_bits_has_four_levels() {
        let mut xs: Vec<f32> = (0..100).map(|i| i as f32 / 99.0).collect();
        dorefa_act(&mut xs, 2);
        let mut levels: Vec<f32> = xs.clone();
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        levels.dedup();
        assert_eq!(levels.len(), 4);
        assert_eq!(levels, vec![0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0]);
        // Clipping
        let mut c = vec![-0.5f32, 1.7];
        dorefa_act(&mut c, 2);
        assert_eq!(c, vec![0.0, 1.0]);
    }

    #[test]
    fn dorefa_grad_unbiased() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let orig = 0.013f32;
        let n = 60_000;
        let mut sum = 0f64;
        for _ in 0..n {
            let mut xs = vec![orig, 0.05, -0.05]; // fixed max magnitude
            dorefa_grad(&mut xs, 6, &mut rng);
            sum += xs[0] as f64;
        }
        let mean = sum / n as f64;
        assert!(
            (mean - orig as f64).abs() < 5e-4,
            "mean={mean} orig={orig}"
        );
    }

    #[test]
    fn wage_weight_ternary_grid() {
        let mut xs = vec![0.9, -0.6, 0.1, 0.0, -1.0];
        wage_weight_2bit(&mut xs);
        // σ = 1.0, step 0.5: values snap to multiples of 0.5 within ±1.
        for v in &xs {
            assert!((v / 0.5).fract().abs() < 1e-6, "v={v}");
            assert!(v.abs() <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn dfp_respects_shared_exponent() {
        let mut xs = vec![100.0, 0.001, -50.0];
        let orig = xs.clone();
        dfp_quantize(&mut xs, 16);
        // Large values nearly exact; the tiny value is quantized on the
        // *shared* grid (step = 128/32767 ≈ 0.0039) → snaps to 0.
        assert!((xs[0] - orig[0]).abs() / orig[0] < 1e-3);
        assert_eq!(xs[1], 0.0);
        assert!((xs[2] - orig[2]).abs() / 50.0 < 1e-3);
    }

    #[test]
    fn wage_error_stochastic_unbiased() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let orig = 0.0123f32;
        let n = 60_000;
        let mut sum = 0f64;
        for _ in 0..n {
            let mut xs = vec![orig, 0.08, -0.08];
            wage_error(&mut xs, 8, &mut rng);
            sum += xs[0] as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - orig as f64).abs() < 2e-4, "mean={mean}");
    }

    #[test]
    fn scheme_ids_roundtrip() {
        for s in [
            BaselineScheme::DoReFa,
            BaselineScheme::Wage,
            BaselineScheme::Dfp16,
            BaselineScheme::MptFp16,
        ] {
            assert_eq!(BaselineScheme::parse(s.id()), Some(s));
        }
    }

    #[test]
    fn empty_and_zero_tensors_are_safe() {
        let mut e: Vec<f32> = vec![];
        dorefa_weight_1bit(&mut e);
        dfp_quantize(&mut e, 16);
        let mut z = vec![0f32; 4];
        let mut rng = Xoshiro256::seed_from_u64(3);
        dorefa_grad(&mut z, 6, &mut rng);
        wage_error(&mut z, 8, &mut rng);
        dfp_quantize(&mut z, 16);
        assert!(z.iter().all(|&v| v == 0.0));
    }
}
