//! The quantization-policy engine: which format/rounding/chunking applies
//! to which tensor of which layer.
//!
//! The paper's scheme (Fig. 2, §3, §4.1) is *positional*: the three GEMMs
//! (Forward / Backward / Gradient) of every Conv/FC layer run FP8×FP8→FP16
//! with chunked accumulation, **except** the last layer (all three GEMMs in
//! FP16 for Softmax fidelity) and the first layer's *data* operand (input
//! images in FP16 since FP8 cannot represent 0..255). The weight-update
//! AXPYs are FP16 with stochastic rounding, and the back-propagated error
//! is loss-scaled by 1000.
//!
//! A [`PrecisionPolicy`] captures one complete experimental configuration;
//! named presets cover the paper's headline scheme and every ablation in
//! Figs. 1/5 and Tables 3/4.

use super::baselines::BaselineScheme;
use crate::numerics::{FloatFormat, GemmPrecision, RoundMode, UpdatePrecision};

/// Which of the three GEMMs of Fig. 2(a) is being computed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GemmRole {
    /// `Y = X · Wᵀ` (activations out).
    Forward,
    /// `dX = dY · W` (errors back).
    Backward,
    /// `dW = dYᵀ · X` (weight gradients; accumulates across the minibatch —
    /// the GEMM §4.2 finds most sensitive to accumulation error).
    Gradient,
}

impl GemmRole {
    pub const ALL: [GemmRole; 3] = [GemmRole::Forward, GemmRole::Backward, GemmRole::Gradient];

    pub fn id(self) -> &'static str {
        match self {
            GemmRole::Forward => "fwd",
            GemmRole::Backward => "bwd",
            GemmRole::Gradient => "grad",
        }
    }

    #[inline]
    fn index(self) -> usize {
        match self {
            GemmRole::Forward => 0,
            GemmRole::Backward => 1,
            GemmRole::Gradient => 2,
        }
    }

    /// The telemetry role this GEMM role reports under.
    #[inline]
    fn telemetry(self) -> crate::telemetry::Role {
        match self {
            GemmRole::Forward => crate::telemetry::Role::Forward,
            GemmRole::Backward => crate::telemetry::Role::Backward,
            GemmRole::Gradient => crate::telemetry::Role::Gradient,
        }
    }
}

/// Where a GEMM layer sits in the network — the paper treats first and last
/// layers specially (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerPos {
    /// Consumes the input image/features (data operand kept in
    /// `input_fmt`).
    First,
    Middle,
    /// Feeds the Softmax (all three GEMMs in FP16 in the paper's scheme).
    Last,
}

/// A complete precision configuration for one training run.
#[derive(Clone, Debug)]
pub struct PrecisionPolicy {
    /// Stable identifier used by the CLI / CSV headers.
    pub name: String,
    /// Per-role GEMM precision for middle (and by default first) layers.
    pub gemm: [GemmPrecision; 3],
    /// Per-role GEMM precision for the last layer.
    pub gemm_last: [GemmPrecision; 3],
    /// Representation format of the network input (the first layer's data
    /// operand). The paper uses FP16 for ImageNet-scale models (§4.1).
    pub input_fmt: FloatFormat,
    /// Format the last-layer Forward-GEMM output (Softmax input) is kept
    /// in. Table 3: preserving this in FP16 is what rescues an FP8 last
    /// layer.
    pub softmax_input_fmt: FloatFormat,
    /// The weight-update AXPY path of Fig. 2(b).
    pub update: UpdatePrecision,
    /// Loss-scaling factor applied to the back-propagated error (§3 adopts
    /// the method of MPT [16] with a single factor of 1000).
    pub loss_scale: f32,
    /// When set, a Table 2 comparison scheme overrides the tensor
    /// quantizers (the GEMM accumulation settings in `gemm`/`gemm_last`
    /// still apply — FP32 for every baseline).
    pub baseline: Option<BaselineScheme>,
}

impl PrecisionPolicy {
    /// Full-precision FP32 baseline.
    pub fn fp32() -> Self {
        Self {
            name: "fp32".into(),
            gemm: [GemmPrecision::fp32(); 3],
            gemm_last: [GemmPrecision::fp32(); 3],
            input_fmt: FloatFormat::FP32,
            softmax_input_fmt: FloatFormat::FP32,
            update: UpdatePrecision::fp32(),
            loss_scale: 1.0,
            baseline: None,
        }
    }

    /// The paper's headline FP8 training scheme (§3): FP8 operands, FP16
    /// chunked accumulation (CL = 64) in all three GEMMs, FP16-SR weight
    /// updates, FP16 last layer and input, loss scale 1000.
    pub fn fp8_paper() -> Self {
        let fp16_gemm = GemmPrecision {
            fmt_mult: FloatFormat::FP16,
            ..GemmPrecision::fp8_paper()
        };
        Self {
            name: "fp8_paper".into(),
            gemm: [GemmPrecision::fp8_paper(); 3],
            gemm_last: [fp16_gemm; 3],
            input_fmt: FloatFormat::FP16,
            softmax_input_fmt: FloatFormat::FP16,
            update: UpdatePrecision::fp16_stochastic(),
            loss_scale: 1000.0,
            baseline: None,
        }
    }

    /// Fig. 1(a): FP8 representations with everything else full precision —
    /// isolates representation error.
    pub fn fp8_reps_only() -> Self {
        let g = GemmPrecision {
            fmt_mult: FloatFormat::FP8,
            fmt_acc: FloatFormat::FP32,
            chunk: usize::MAX,
            round: RoundMode::NearestEven,
            exact: false,
        };
        Self {
            name: "fp8_reps_only".into(),
            gemm: [g; 3],
            gemm_last: [g; 3],
            input_fmt: FloatFormat::FP32,
            softmax_input_fmt: FloatFormat::FP32,
            update: UpdatePrecision::fp32(),
            loss_scale: 1.0,
            baseline: None,
        }
    }

    /// Fig. 1(b): FP16 accumulation *without chunking* (FP32 operands) —
    /// isolates swamping in the accumulator.
    pub fn fp16_acc_nochunk() -> Self {
        let g = GemmPrecision {
            fmt_mult: FloatFormat::FP32,
            fmt_acc: FloatFormat::FP16,
            chunk: 1,
            round: RoundMode::NearestEven,
            exact: true,
        };
        Self {
            name: "fp16_acc_nochunk".into(),
            gemm: [g; 3],
            gemm_last: [g; 3],
            input_fmt: FloatFormat::FP32,
            softmax_input_fmt: FloatFormat::FP32,
            update: UpdatePrecision::fp32(),
            loss_scale: 1.0,
            baseline: None,
        }
    }

    /// Fig. 1(c) / Table 4: FP16 weight updates with nearest rounding
    /// (GEMMs full precision) — isolates update swamping.
    pub fn fp16_upd_nearest() -> Self {
        Self {
            name: "fp16_upd_nearest".into(),
            update: UpdatePrecision::fp16_nearest(),
            loss_scale: 1.0,
            ..Self::fp32()
        }
        .renamed("fp16_upd_nearest")
    }

    /// Table 4 counterpart: FP16 updates with stochastic rounding, FP32
    /// GEMMs.
    pub fn fp16_upd_stochastic() -> Self {
        Self {
            update: UpdatePrecision::fp16_stochastic(),
            loss_scale: 1.0,
            ..Self::fp32()
        }
        .renamed("fp16_upd_stochastic")
    }

    /// Fig. 5(a): the paper's scheme with chunking disabled (CL = 1).
    pub fn fp8_nochunk() -> Self {
        let mut p = Self::fp8_paper();
        for g in p.gemm.iter_mut().chain(p.gemm_last.iter_mut()) {
            g.chunk = 1;
            g.exact = true;
        }
        p.renamed("fp8_nochunk")
    }

    /// Fig. 5(b): no chunking, but exactly one GEMM role promoted to FP32
    /// accumulation.
    pub fn fp8_nochunk_fp32_role(role: GemmRole) -> Self {
        let mut p = Self::fp8_nochunk();
        p.gemm[role.index()].fmt_acc = FloatFormat::FP32;
        p.gemm[role.index()].exact = false;
        p.gemm_last[role.index()].fmt_acc = FloatFormat::FP32;
        p.gemm_last[role.index()].exact = false;
        p.renamed(&format!("fp8_nochunk_fp32_{}", role.id()))
    }

    /// Table 3 variants: last-layer GEMM operand format and Softmax-input
    /// format.
    pub fn with_last_layer(mut self, fmt: FloatFormat, softmax_input: FloatFormat) -> Self {
        for g in self.gemm_last.iter_mut() {
            g.fmt_mult = fmt;
        }
        self.softmax_input_fmt = softmax_input;
        let name = format!("{}_last_{}_sm_{}", self.name, fmt.name(), softmax_input.name());
        self.renamed(&name)
    }

    /// Override the chunk size everywhere (Fig. 6 sweeps).
    pub fn with_chunk(mut self, chunk: usize) -> Self {
        for g in self.gemm.iter_mut().chain(self.gemm_last.iter_mut()) {
            if !g.is_fp32() {
                g.chunk = chunk;
            }
        }
        self
    }

    /// Override the GEMM accumulation rounding mode everywhere but the
    /// FP32 paths (the `sweep` round-mode axis — nearest vs stochastic vs
    /// truncate on otherwise-identical cells). The weight-update path is
    /// deliberately untouched: its rounding is part of the update scheme
    /// (Table 4), not of the GEMM accumulation study.
    pub fn with_round(mut self, round: RoundMode) -> Self {
        for g in self.gemm.iter_mut().chain(self.gemm_last.iter_mut()) {
            if !g.is_fp32() {
                g.round = round;
            }
        }
        self
    }

    pub fn renamed(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    /// A Table 2 baseline scheme: custom tensor quantizers, FP32 GEMM
    /// accumulation, FP32 weight updates (DoReFa/WAGE/DFP/MPT all keep
    /// FP32 master weights; MPT additionally loss-scales).
    pub fn baseline(scheme: BaselineScheme) -> Self {
        let mut p = Self::fp32();
        p.baseline = Some(scheme);
        p.loss_scale = match scheme {
            BaselineScheme::MptFp16 => 1000.0,
            _ => 1.0,
        };
        p.renamed(scheme.id())
    }

    /// Quantize a *stored activation* tensor (data operand) in place.
    /// Wall time lands in the `quantize` phase of [`crate::perf`].
    pub fn quantize_act(&self, xs: &mut [f32], role: GemmRole, pos: LayerPos) {
        crate::perf::timed(crate::perf::Phase::Quantize, || {
            let _role = crate::telemetry::role_scope(role.telemetry());
            match self.baseline {
                // Baselines keep first/last layers full precision ([23], [3]
                // — see §4.1's discussion of this convention).
                Some(s) if pos == LayerPos::Middle => s.quantize_act(xs),
                Some(_) => {}
                None => self
                    .act_fmt(role, pos)
                    .quantize_batch(xs, RoundMode::NearestEven),
            }
        })
    }

    /// Quantize a weight tensor in place at GEMM time. (The hot layers no
    /// longer call this per GEMM — weight operands come from the
    /// version-keyed quantized-pack cache, see `docs/perf.md` — but
    /// baseline schemes and experiment harnesses still route through it.)
    pub fn quantize_weight(&self, xs: &mut [f32], role: GemmRole, pos: LayerPos) {
        crate::perf::timed(crate::perf::Phase::Quantize, || {
            let _role = crate::telemetry::role_scope(role.telemetry());
            match self.baseline {
                Some(s) if pos == LayerPos::Middle => s.quantize_weight(xs),
                Some(_) => {}
                None => self
                    .weight_fmt(role, pos)
                    .quantize_batch(xs, RoundMode::NearestEven),
            }
        })
    }

    /// Quantize a stored error tensor in place (`seed` drives the
    /// stochastic baseline gradient quantizers).
    pub fn quantize_err(&self, xs: &mut [f32], role: GemmRole, pos: LayerPos, seed: u64) {
        crate::perf::timed(crate::perf::Phase::Quantize, || {
            let _role = crate::telemetry::role_scope(role.telemetry());
            match self.baseline {
                Some(s) if pos == LayerPos::Middle => s.quantize_err(xs, seed),
                Some(_) => {}
                None => self
                    .err_fmt(role, pos)
                    .quantize_batch(xs, RoundMode::NearestEven),
            }
        })
    }

    /// The data-path quantizer for a stored tensor **when it is a plain
    /// single-format nearest-even pass** — the condition for the fused /
    /// cached operand-preparation fast paths (`docs/perf.md`). Table 2
    /// baseline schemes return `None` (their custom quantizers are neither
    /// cacheable by format key nor fusable into copy passes) and the layer
    /// falls back to the explicit clone-and-quantize dataflow.
    #[inline]
    pub fn plain_act_fmt(&self, role: GemmRole, pos: LayerPos) -> Option<FloatFormat> {
        match self.baseline {
            Some(_) => None,
            None => Some(self.act_fmt(role, pos)),
        }
    }

    /// [`plain_act_fmt`](Self::plain_act_fmt) for the weight operand.
    #[inline]
    pub fn plain_weight_fmt(&self, role: GemmRole, pos: LayerPos) -> Option<FloatFormat> {
        match self.baseline {
            Some(_) => None,
            None => Some(self.weight_fmt(role, pos)),
        }
    }

    /// [`plain_act_fmt`](Self::plain_act_fmt) for the error operand.
    #[inline]
    pub fn plain_err_fmt(&self, role: GemmRole, pos: LayerPos) -> Option<FloatFormat> {
        match self.baseline {
            Some(_) => None,
            None => Some(self.err_fmt(role, pos)),
        }
    }

    /// Named-preset lookup for the CLI.
    pub fn parse(name: &str) -> Option<Self> {
        Some(match name {
            "fp32" => Self::fp32(),
            "fp8_paper" | "fp8" => Self::fp8_paper(),
            "fp8_reps_only" => Self::fp8_reps_only(),
            "fp16_acc_nochunk" => Self::fp16_acc_nochunk(),
            "fp16_upd_nearest" => Self::fp16_upd_nearest(),
            "fp16_upd_stochastic" => Self::fp16_upd_stochastic(),
            "fp8_nochunk" => Self::fp8_nochunk(),
            "fp8_nochunk_fp32_fwd" => Self::fp8_nochunk_fp32_role(GemmRole::Forward),
            "fp8_nochunk_fp32_bwd" => Self::fp8_nochunk_fp32_role(GemmRole::Backward),
            "fp8_nochunk_fp32_grad" => Self::fp8_nochunk_fp32_role(GemmRole::Gradient),
            _ => return BaselineScheme::parse(name).map(Self::baseline),
        })
    }

    pub const PRESETS: [&'static str; 10] = [
        "fp32",
        "fp8_paper",
        "fp8_reps_only",
        "fp16_acc_nochunk",
        "fp16_upd_nearest",
        "fp16_upd_stochastic",
        "fp8_nochunk",
        "fp8_nochunk_fp32_fwd",
        "fp8_nochunk_fp32_bwd",
        "fp8_nochunk_fp32_grad",
    ];

    /// Build a policy from a JSON object — the sweep's `--policy-json`
    /// escape hatch for configurations outside the preset list
    /// (`docs/sweep.md`).
    ///
    /// Required: `"name"` (must not shadow a preset — the name keys sweep
    /// cells and CSV rows, so aliasing a preset would silently merge
    /// cells). Optional `"base"` names the preset that seeds every knob
    /// (default `fp8_paper`); the remaining keys override it:
    /// `"fmt"` / `"last_fmt"` (GEMM operand format for middle/last
    /// layers), `"acc_fmt"` (accumulation format, all GEMMs),
    /// `"input_fmt"`, `"softmax_input_fmt"` (float-format names),
    /// `"chunk"` (accumulation chunk length; `0` means unchunked),
    /// `"round"` (GEMM accumulation rounding: `nearest` / `nearest_away`
    /// / `truncate` / `stochastic`), `"update"` (`fp32` /
    /// `fp16_stochastic` / `fp16_nearest`) and `"loss_scale"`. Unknown
    /// keys are rejected so a typo cannot silently train the base policy.
    pub fn from_json(text: &str) -> Result<Self, String> {
        use crate::benchcmp::Json;
        const KNOWN: [&str; 11] = [
            "name",
            "base",
            "fmt",
            "last_fmt",
            "acc_fmt",
            "input_fmt",
            "softmax_input_fmt",
            "chunk",
            "round",
            "update",
            "loss_scale",
        ];
        let v = Json::parse(text).map_err(|e| format!("policy json: {e}"))?;
        let Json::Obj(m) = &v else {
            return Err("policy json: top level must be an object".into());
        };
        for k in m.keys() {
            if !KNOWN.contains(&k.as_str()) {
                return Err(format!(
                    "policy json: unknown key {k:?} (known: {})",
                    KNOWN.join(", ")
                ));
            }
        }
        let str_of = |k: &str| -> Result<Option<&str>, String> {
            match m.get(k) {
                None => Ok(None),
                Some(v) => v
                    .str_val()
                    .map(Some)
                    .ok_or_else(|| format!("policy json: {k} must be a string")),
            }
        };
        let fmt_of = |k: &str| -> Result<Option<FloatFormat>, String> {
            match str_of(k)? {
                None => Ok(None),
                Some(s) => FloatFormat::parse(s)
                    .map(Some)
                    .ok_or_else(|| format!("policy json: unknown float format {s:?} for {k}")),
            }
        };
        let name = str_of("name")?
            .ok_or_else(|| "policy json: required key \"name\" missing".to_string())?;
        if name.is_empty() {
            return Err("policy json: name must be non-empty".into());
        }
        if Self::parse(name).is_some() {
            return Err(format!(
                "policy json: name {name:?} shadows a built-in policy"
            ));
        }
        let base = str_of("base")?.unwrap_or("fp8_paper");
        let mut p = Self::parse(base)
            .ok_or_else(|| format!("policy json: unknown base policy {base:?}"))?;
        if let Some(f) = fmt_of("fmt")? {
            for g in p.gemm.iter_mut() {
                g.fmt_mult = f;
            }
        }
        if let Some(f) = fmt_of("last_fmt")? {
            for g in p.gemm_last.iter_mut() {
                g.fmt_mult = f;
            }
        }
        if let Some(f) = fmt_of("acc_fmt")? {
            for g in p.gemm.iter_mut().chain(p.gemm_last.iter_mut()) {
                g.fmt_acc = f;
            }
        }
        if let Some(f) = fmt_of("input_fmt")? {
            p.input_fmt = f;
        }
        if let Some(f) = fmt_of("softmax_input_fmt")? {
            p.softmax_input_fmt = f;
        }
        if let Some(v) = m.get("chunk") {
            let n = v
                .num()
                .ok_or_else(|| "policy json: chunk must be a number".to_string())?;
            if n < 0.0 || n.fract() != 0.0 {
                return Err(format!("policy json: chunk must be a non-negative integer, got {n}"));
            }
            p = p.with_chunk(if n == 0.0 { usize::MAX } else { n as usize });
        }
        if let Some(s) = str_of("round")? {
            let r = RoundMode::parse(s)
                .ok_or_else(|| format!("policy json: unknown round mode {s:?}"))?;
            p = p.with_round(r);
        }
        if let Some(s) = str_of("update")? {
            p.update = match s {
                "fp32" => UpdatePrecision::fp32(),
                "fp16_stochastic" => UpdatePrecision::fp16_stochastic(),
                "fp16_nearest" => UpdatePrecision::fp16_nearest(),
                other => {
                    return Err(format!(
                        "policy json: unknown update scheme {other:?} \
                         (fp32 | fp16_stochastic | fp16_nearest)"
                    ))
                }
            };
        }
        if let Some(v) = m.get("loss_scale") {
            let n = v
                .num()
                .ok_or_else(|| "policy json: loss_scale must be a number".to_string())?;
            if !(n > 0.0 && n.is_finite()) {
                return Err(format!("policy json: loss_scale must be positive, got {n}"));
            }
            p.loss_scale = n as f32;
        }
        Ok(p.renamed(name))
    }

    /// The GEMM precision for `role` at layer position `pos`.
    #[inline]
    pub fn gemm_for(&self, role: GemmRole, pos: LayerPos) -> GemmPrecision {
        match pos {
            LayerPos::Last => self.gemm_last[role.index()],
            _ => self.gemm[role.index()],
        }
    }

    /// Format for the *data* operand (activations into Forward/Gradient
    /// GEMMs) at `pos`. First layers keep the network input in
    /// `input_fmt` (§4.1); elsewhere the GEMM's multiply format applies.
    #[inline]
    pub fn act_fmt(&self, role: GemmRole, pos: LayerPos) -> FloatFormat {
        let base = self.gemm_for(role, pos).fmt_mult;
        match pos {
            LayerPos::First => {
                // Input images are FP16; weights stay FP8. Use the *wider*
                // of the two so FP32 baselines are unaffected.
                if self.input_fmt.mbits > base.mbits {
                    self.input_fmt
                } else {
                    base
                }
            }
            _ => base,
        }
    }

    /// Format for the weight operand at `pos`.
    #[inline]
    pub fn weight_fmt(&self, role: GemmRole, pos: LayerPos) -> FloatFormat {
        self.gemm_for(role, pos).fmt_mult
    }

    /// Format for the error operand (dY into Backward/Gradient GEMMs).
    #[inline]
    pub fn err_fmt(&self, role: GemmRole, pos: LayerPos) -> FloatFormat {
        self.gemm_for(role, pos).fmt_mult
    }

    /// Does any part of the policy use stochastic rounding (and therefore
    /// consume RNG state)?
    pub fn is_stochastic(&self) -> bool {
        self.update.round.is_stochastic()
            || self
                .gemm
                .iter()
                .chain(self.gemm_last.iter())
                .any(|g| g.round.is_stochastic())
    }
}

/// Per-step quantization context threaded through every layer: the policy,
/// a step counter (diversifies SR streams across steps), and train/eval
/// mode.
#[derive(Clone, Debug)]
pub struct QuantCtx<'a> {
    pub policy: &'a PrecisionPolicy,
    pub step: u64,
    pub train: bool,
}

impl<'a> QuantCtx<'a> {
    pub fn new(policy: &'a PrecisionPolicy, step: u64, train: bool) -> Self {
        Self { policy, step, train }
    }

    /// Deterministic per-(layer, role, step) seed for stochastic rounding
    /// inside GEMMs — results are independent of scheduling and replayable.
    #[inline]
    pub fn gemm_seed(&self, layer_id: u64, role: GemmRole) -> u64 {
        splitmix_once(
            self.step
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(layer_id << 8)
                .wrapping_add(role.index() as u64),
        )
    }
}

#[inline]
fn splitmix_once(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_json_overrides_ride_on_the_base_preset() {
        let p = PrecisionPolicy::from_json(
            r#"{"name":"e4m3_cl32","base":"fp8_paper","fmt":"e4m3",
                "chunk":32,"round":"stochastic","loss_scale":500}"#,
        )
        .unwrap();
        assert_eq!(p.name, "e4m3_cl32");
        assert_eq!(p.loss_scale, 500.0);
        for role in GemmRole::ALL {
            let g = p.gemm_for(role, LayerPos::Middle);
            assert_eq!(g.fmt_mult.name(), "e4m3");
            assert_eq!(g.chunk, 32);
            assert!(g.round.is_stochastic());
            // last_fmt untouched: the base's FP16 last layer survives.
            assert_eq!(p.gemm_for(role, LayerPos::Last).fmt_mult, FloatFormat::FP16);
        }
        // Base knobs not mentioned in the JSON carry over.
        assert_eq!(p.input_fmt, FloatFormat::FP16);
        assert!(p.update.round.is_stochastic());
    }

    #[test]
    fn from_json_full_knob_coverage_and_chunk_zero() {
        let p = PrecisionPolicy::from_json(
            r#"{"name":"wide","base":"fp32","fmt":"bf16","last_fmt":"fp16",
                "acc_fmt":"fp16","input_fmt":"fp32","softmax_input_fmt":"fp32",
                "chunk":0,"update":"fp16_nearest"}"#,
        )
        .unwrap();
        let g = p.gemm_for(GemmRole::Forward, LayerPos::Middle);
        assert_eq!(g.fmt_mult.name(), "bf16");
        assert_eq!(g.fmt_acc, FloatFormat::FP16);
        assert_eq!(g.chunk, usize::MAX, "chunk 0 means unchunked");
        assert_eq!(
            p.gemm_for(GemmRole::Forward, LayerPos::Last).fmt_mult,
            FloatFormat::FP16
        );
        assert_eq!(p.update.fmt, FloatFormat::FP16);
        assert!(!p.update.round.is_stochastic());
    }

    #[test]
    fn from_json_rejects_bad_inputs_loudly() {
        let cases = [
            ("{}", "required key \"name\""),
            (r#"{"name":"fp8_paper"}"#, "shadows a built-in"),
            (r#"{"name":"x","typo_fmt":"fp8"}"#, "unknown key"),
            (r#"{"name":"x","base":"nope"}"#, "unknown base"),
            (r#"{"name":"x","fmt":"e9m9"}"#, "unknown float format"),
            (r#"{"name":"x","chunk":-3}"#, "non-negative integer"),
            (r#"{"name":"x","round":"down"}"#, "unknown round mode"),
            (r#"{"name":"x","update":"int8"}"#, "unknown update scheme"),
            (r#"{"name":"x","loss_scale":0}"#, "must be positive"),
            ("[1,2]", "must be an object"),
            ("{", "policy json"),
        ];
        for (text, want) in cases {
            let err = PrecisionPolicy::from_json(text).unwrap_err();
            assert!(err.contains(want), "{text} → {err}");
        }
    }

    #[test]
    fn paper_policy_shape() {
        let p = PrecisionPolicy::fp8_paper();
        assert_eq!(p.loss_scale, 1000.0);
        assert_eq!(p.input_fmt, FloatFormat::FP16);
        for role in GemmRole::ALL {
            let g = p.gemm_for(role, LayerPos::Middle);
            assert_eq!(g.fmt_mult, FloatFormat::FP8);
            assert_eq!(g.fmt_acc, FloatFormat::FP16);
            assert_eq!(g.chunk, 64);
            // Last layer runs FP16 operands (§4.1 / Table 3).
            let l = p.gemm_for(role, LayerPos::Last);
            assert_eq!(l.fmt_mult, FloatFormat::FP16);
        }
        assert_eq!(p.update.fmt, FloatFormat::FP16);
        assert!(p.update.round.is_stochastic());
        assert!(p.is_stochastic());
    }

    #[test]
    fn first_layer_keeps_wide_input() {
        let p = PrecisionPolicy::fp8_paper();
        // Data operand of the first Forward GEMM: FP16; weights stay FP8.
        assert_eq!(p.act_fmt(GemmRole::Forward, LayerPos::First), FloatFormat::FP16);
        assert_eq!(p.weight_fmt(GemmRole::Forward, LayerPos::First), FloatFormat::FP8);
        assert_eq!(p.act_fmt(GemmRole::Forward, LayerPos::Middle), FloatFormat::FP8);
        // FP32 baseline unaffected by the input-format rule.
        let b = PrecisionPolicy::fp32();
        assert_eq!(b.act_fmt(GemmRole::Forward, LayerPos::First), FloatFormat::FP32);
        assert!(!b.is_stochastic());
    }

    #[test]
    fn all_presets_parse_and_roundtrip() {
        for name in PrecisionPolicy::PRESETS {
            let p = PrecisionPolicy::parse(name).unwrap_or_else(|| panic!("preset {name}"));
            assert_eq!(p.name, name);
        }
        assert!(PrecisionPolicy::parse("nope").is_none());
    }

    #[test]
    fn fig5b_promotes_exactly_one_role() {
        let p = PrecisionPolicy::fp8_nochunk_fp32_role(GemmRole::Gradient);
        assert_eq!(
            p.gemm_for(GemmRole::Gradient, LayerPos::Middle).fmt_acc,
            FloatFormat::FP32
        );
        assert_eq!(
            p.gemm_for(GemmRole::Forward, LayerPos::Middle).fmt_acc,
            FloatFormat::FP16
        );
        assert_eq!(p.gemm_for(GemmRole::Forward, LayerPos::Middle).chunk, 1);
    }

    #[test]
    fn chunk_override_spares_fp32() {
        let p = PrecisionPolicy::fp8_paper().with_chunk(128);
        assert_eq!(p.gemm_for(GemmRole::Forward, LayerPos::Middle).chunk, 128);
        let b = PrecisionPolicy::fp32().with_chunk(128);
        assert!(b.gemm_for(GemmRole::Forward, LayerPos::Middle).is_fp32());
    }

    #[test]
    fn round_override_spares_fp32_and_update_path() {
        let p = PrecisionPolicy::fp8_paper().with_round(RoundMode::Stochastic);
        assert_eq!(
            p.gemm_for(GemmRole::Forward, LayerPos::Middle).round,
            RoundMode::Stochastic
        );
        assert_eq!(
            p.gemm_for(GemmRole::Gradient, LayerPos::Last).round,
            RoundMode::Stochastic
        );
        // The update AXPY keeps its own scheme.
        assert_eq!(p.update.round, PrecisionPolicy::fp8_paper().update.round);
        let b = PrecisionPolicy::fp32().with_round(RoundMode::Truncate);
        assert!(b.gemm_for(GemmRole::Forward, LayerPos::Middle).is_fp32());
        assert_eq!(
            b.gemm_for(GemmRole::Forward, LayerPos::Middle).round,
            PrecisionPolicy::fp32().gemm_for(GemmRole::Forward, LayerPos::Middle).round
        );
    }

    #[test]
    fn table3_last_layer_variants() {
        let p = PrecisionPolicy::fp8_paper().with_last_layer(FloatFormat::FP8, FloatFormat::FP16);
        assert_eq!(p.gemm_for(GemmRole::Forward, LayerPos::Last).fmt_mult, FloatFormat::FP8);
        assert_eq!(p.softmax_input_fmt, FloatFormat::FP16);
    }

    #[test]
    fn gemm_seeds_vary_by_layer_role_step() {
        let p = PrecisionPolicy::fp8_paper();
        let c1 = QuantCtx::new(&p, 1, true);
        let c2 = QuantCtx::new(&p, 2, true);
        let s = c1.gemm_seed(0, GemmRole::Forward);
        assert_ne!(s, c1.gemm_seed(1, GemmRole::Forward));
        assert_ne!(s, c1.gemm_seed(0, GemmRole::Backward));
        assert_ne!(s, c2.gemm_seed(0, GemmRole::Forward));
        // Deterministic.
        assert_eq!(s, QuantCtx::new(&p, 1, true).gemm_seed(0, GemmRole::Forward));
    }
}
