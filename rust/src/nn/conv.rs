//! 2-D convolution, lowered to GEMM per §2.2 ("the convolution computation
//! is implemented by first lowering the input data, followed by GEMM
//! operations").
//!
//! After im2col, the three GEMMs and their dot-product lengths are:
//!
//! ```text
//! Forward:   Y[N·oh·ow, oc]   = Colsq · Wqᵀ        K = in_c·k·k
//! Backward:  dCols             = dYq · Wq           K = oc
//! Gradient:  dW[oc, in_c·k·k]  = dYqᵀ · Colsq       K = N·oh·ow  ← longest;
//!                                                    the GEMM §4.2 shows is
//!                                                    most swamping-sensitive
//! ```
//!
//! Quantization points mirror [`super::linear::Linear`]: activations and
//! errors are quantized once where they are produced/stored, weights at
//! GEMM time.

use super::linear::layer_hash;
use super::quant::{GemmRole, LayerPos, QuantCtx};
use super::{Layer, Param};
use crate::numerics::Xoshiro256;
use crate::tensor::{col2im, im2col, init, Conv2dGeom, Tensor};

pub struct Conv2d {
    pub w: Param, // [oc, in_c·k·k]
    pub b: Option<Param>,
    pub geom: Conv2dGeom,
    pub out_c: usize,
    pub pos: LayerPos,
    layer_id: u64,
    // backward caches
    cols_q: Option<Tensor>,
    w_q: Option<Tensor>,
    batch: usize,
    /// When set, [`Layer::backward`] stores the Gradient-GEMM operands
    /// (error rows, activation patch matrix) for the Fig. 6 harness.
    pub capture: bool,
    pub captured: Option<(Tensor, Tensor)>,
}

impl Conv2d {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        geom: Conv2dGeom,
        out_c: usize,
        pos: LayerPos,
        bias: bool,
        rng: &mut Xoshiro256,
    ) -> Self {
        let fan_in = geom.patch_len();
        let w = init::kaiming_normal(&[out_c, fan_in], fan_in, rng);
        Self {
            w: Param::new(format!("{name}.w"), w, true),
            b: bias.then(|| Param::new(format!("{name}.b"), Tensor::zeros(&[out_c]), false)),
            geom,
            out_c,
            pos,
            layer_id: layer_hash(name),
            cols_q: None,
            w_q: None,
            batch: 0,
            capture: false,
            captured: None,
        }
    }

    pub fn out_shape(&self, n: usize) -> [usize; 4] {
        [n, self.out_c, self.geom.out_h(), self.geom.out_w()]
    }
}

/// `[N·oh·ow, oc]` GEMM-output rows → NCHW.
fn rows_to_nchw(rows: &Tensor, n: usize, oc: usize, oh: usize, ow: usize) -> Tensor {
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    for img in 0..n {
        for s in 0..oh * ow {
            let row = (img * oh * ow + s) * oc;
            for c in 0..oc {
                out.data[((img * oc) + c) * oh * ow + s] = rows.data[row + c];
            }
        }
    }
    out
}

/// NCHW → `[N·oh·ow, oc]` rows (adjoint of [`rows_to_nchw`]). The result is
/// a step-local temporary, so it leases from the scratch arena.
fn nchw_to_rows(x: &Tensor) -> Tensor {
    let (n, oc, oh, ow) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros_pooled(&[n * oh * ow, oc]);
    for img in 0..n {
        for s in 0..oh * ow {
            let row = (img * oh * ow + s) * oc;
            for c in 0..oc {
                out.data[row + c] = x.data[((img * oc) + c) * oh * ow + s];
            }
        }
    }
    out
}

impl Layer for Conv2d {
    fn forward(&mut self, x: Tensor, ctx: &QuantCtx) -> Tensor {
        assert_eq!(x.ndim(), 4, "conv expects NCHW");
        let n = x.shape[0];
        let p = ctx.policy;

        // Stored activation: quantize before lowering (padding zeros are
        // exactly representable, so quantize-then-im2col == im2col-then-
        // quantize; the former quantizes C·H·W instead of C·k²·oh·ow
        // values).
        let mut x_q = x;
        p.quantize_act(&mut x_q.data, GemmRole::Forward, self.pos);
        let cols_q = im2col(&x_q, &self.geom);

        let mut w_q = self.w.value.clone();
        p.quantize_weight(&mut w_q.data, GemmRole::Forward, self.pos);

        let prec = p.gemm_for(GemmRole::Forward, self.pos);
        // W is stored [oc, in_c·k·k] — already the packed-Bᵀ layout for
        // Y = Cols·Wᵀ, so the forward GEMM performs no transpose.
        let mut rows = cols_q.matmul_t(
            &w_q,
            &prec,
            ctx.gemm_seed(self.layer_id, GemmRole::Forward),
        );
        if let Some(b) = &self.b {
            rows.add_row(&b.value.data);
        }
        let (oh, ow) = (self.geom.out_h(), self.geom.out_w());
        let y = rows_to_nchw(&rows, n, self.out_c, oh, ow);
        rows.recycle();
        if ctx.train {
            self.cols_q = Some(cols_q);
            self.w_q = Some(w_q);
            self.batch = n;
        } else {
            // Eval drops the caches immediately — return the big patch
            // matrix (and the weight copy) to the arena so eval loops
            // re-lease instead of re-allocating every batch.
            cols_q.recycle();
            w_q.recycle();
        }
        y
    }

    fn backward(&mut self, dy: Tensor, ctx: &QuantCtx) -> Tensor {
        let p = ctx.policy;
        let cols_q = self.cols_q.take().expect("backward before forward");
        let w_q = self.w_q.take().expect("backward before forward");
        let n = self.batch;
        assert_eq!(dy.shape, self.out_shape(n).to_vec());

        let mut err = nchw_to_rows(&dy); // [N·oh·ow, oc]
        if let Some(b) = &mut self.b {
            for (g, v) in b.grad.data.iter_mut().zip(err.sum_rows()) {
                *g += v;
            }
        }
        p.quantize_err(
            &mut err.data,
            GemmRole::Backward,
            self.pos,
            ctx.gemm_seed(self.layer_id, GemmRole::Backward) ^ 0xE44,
        );

        if self.capture {
            self.captured = Some((err.clone(), cols_q.clone()));
        }

        // Gradient GEMM: dW = errᵀ · cols, K = N·oh·ow. The transposed
        // error operand is a step-local temporary → scratch arena.
        let prec_g = p.gemm_for(GemmRole::Gradient, self.pos);
        let err_t = err.t_pooled();
        let dw = err_t.matmul(
            &cols_q,
            &prec_g,
            ctx.gemm_seed(self.layer_id, GemmRole::Gradient),
        );
        err_t.recycle();
        self.w.grad.add_assign(&dw);
        dw.recycle();

        // Backward GEMM: dCols = err · Wq, then col2im scatter.
        let prec_b = p.gemm_for(GemmRole::Backward, self.pos);
        let dcols = err.matmul(
            &w_q,
            &prec_b,
            ctx.gemm_seed(self.layer_id, GemmRole::Backward),
        );
        let dx = col2im(&dcols, &self.geom, n);
        // Everything whose lifetime ended this step goes back to the arena.
        dcols.recycle();
        err.recycle();
        cols_q.recycle();
        w_q.recycle();
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.w);
        if let Some(b) = &mut self.b {
            f(b);
        }
    }

    fn name(&self) -> String {
        self.w.name.trim_end_matches(".w").to_string()
    }

    fn macs_per_example(&self) -> u64 {
        (self.geom.out_h() * self.geom.out_w() * self.out_c * self.geom.patch_len()) as u64
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::PrecisionPolicy;

    fn small_geom() -> Conv2dGeom {
        Conv2dGeom {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            k: 3,
            stride: 1,
            pad: 1,
        }
    }

    #[test]
    fn forward_shape_and_layout() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut c = Conv2d::new("c1", small_geom(), 4, LayerPos::Middle, true, &mut rng);
        let x = Tensor::zeros(&[3, 2, 5, 5]);
        let y = c.forward(x, &ctx);
        assert_eq!(y.shape, vec![3, 4, 5, 5]);
    }

    #[test]
    fn rows_nchw_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x = Tensor::from_vec(
            &[2, 3, 4, 4],
            (0..96).map(|_| rng.uniform(-1.0, 1.0)).collect(),
        );
        let rows = nchw_to_rows(&x);
        assert_eq!(rows.shape, vec![32, 3]);
        let back = rows_to_nchw(&rows, 2, 3, 4, 4);
        assert_eq!(back, x);
    }

    #[test]
    fn conv_gradcheck_fp32() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let g = Conv2dGeom {
            in_c: 1,
            in_h: 4,
            in_w: 4,
            k: 3,
            stride: 1,
            pad: 1,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut c = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut rng);
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| 0.1 * i as f32 - 0.8).collect());
        let dy_data: Vec<f32> = (0..32).map(|i| ((i * 13 % 7) as f32 - 3.0) / 10.0).collect();
        let dy = Tensor::from_vec(&[1, 2, 4, 4], dy_data);

        c.forward(x.clone(), &ctx);
        let dx = c.backward(dy.clone(), &ctx);

        // finite differences on x
        let eps = 1e-2f32;
        for i in (0..16).step_by(3) {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let mut cp = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            let mut cm = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            let yp = cp.forward(xp, &ctx);
            let ym = cm.forward(xm, &ctx);
            let fp: f32 = yp.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let fm: f32 = ym.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 1e-2,
                "dx[{i}]: numeric {num} vs analytic {}",
                dx.data[i]
            );
        }

        // finite differences on w (a few entries)
        let dw = c.w.grad.clone();
        for i in (0..c.w.value.len()).step_by(5) {
            let mut cp = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            let mut cm = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            cp.w.value.data[i] += eps;
            cm.w.value.data[i] -= eps;
            let yp = cp.forward(x.clone(), &ctx);
            let ym = cm.forward(x.clone(), &ctx);
            let fp: f32 = yp.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let fm: f32 = ym.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - dw.data[i]).abs() < 2e-2,
                "dw[{i}]: numeric {num} vs analytic {}",
                dw.data[i]
            );
        }
    }

    #[test]
    fn macs_count() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let c = Conv2d::new("c", small_geom(), 4, LayerPos::Middle, false, &mut rng);
        // 5·5 output sites × 4 out channels × 18 patch = 1800 MACs.
        assert_eq!(c.macs_per_example(), 1800);
    }

    #[test]
    fn strided_conv_shapes() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let g = Conv2dGeom {
            in_c: 3,
            in_h: 8,
            in_w: 8,
            k: 3,
            stride: 2,
            pad: 1,
        };
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut c = Conv2d::new("c", g, 6, LayerPos::Middle, false, &mut rng);
        let y = c.forward(Tensor::zeros(&[2, 3, 8, 8]), &ctx);
        assert_eq!(y.shape, vec![2, 6, 4, 4]);
        let dx = c.backward(Tensor::zeros(&[2, 6, 4, 4]), &ctx);
        assert_eq!(dx.shape, vec![2, 3, 8, 8]);
    }
}
