//! 2-D convolution, lowered to GEMM per §2.2 ("the convolution computation
//! is implemented by first lowering the input data, followed by GEMM
//! operations").
//!
//! After im2col, the three GEMMs and their dot-product lengths are:
//!
//! ```text
//! Forward:   Y[N·oh·ow, oc]   = Colsq · Wqᵀ        K = in_c·k·k
//! Backward:  dCols             = dYq · Wq           K = oc
//! Gradient:  dW[oc, in_c·k·k]  = dYqᵀ · Colsq       K = N·oh·ow  ← longest;
//!                                                    the GEMM §4.2 shows is
//!                                                    most swamping-sensitive
//! ```
//!
//! Quantization points mirror [`super::linear::Linear`]: activations and
//! errors are quantized once where they are produced/stored — **fused into
//! the copy passes that already exist** where that is a win (errors always
//! fuse into the NCHW→rows repack, which copies each element exactly once;
//! activations fuse into the im2col lowering only when it replicates each
//! source element into few patches — dense kernels keep the single
//! vectorized pre-lowering pass). Both routes are bit-identical
//! (`docs/perf.md`). Weight operands come from the weight tensor's
//! version-keyed quantized pack cache (quantized once per update, no
//! per-GEMM clone). Table 2 baseline schemes keep the explicit two-pass
//! dataflow.

use super::linear::layer_hash;
use super::quant::{GemmRole, LayerPos, QuantCtx};
use super::{Layer, Param};
use crate::numerics::format::NeQuantizer;
use crate::numerics::{RoundMode, Xoshiro256};
use crate::tensor::{col2im, im2col, im2col_q, init, scratch, Conv2dGeom, Tensor};

/// Whether the forward im2col lowering fuses quantization into the copy
/// pass for this geometry. A pure function of the geometry, decided once
/// per layer (at construction, and again by the program lowering —
/// `crate::program` must agree with the interpreter op-for-op): fuse when
/// the lowering replicates each source element into few patches (1×1
/// kernels, heavily strided convs); dense kernels replicate ~(k/stride)²
/// times and keep the single vectorized pre-lowering quantize pass.
pub fn im2col_fuses(g: &Conv2dGeom) -> bool {
    g.out_h() * g.out_w() * g.k * g.k <= 2 * g.in_h * g.in_w
}

pub struct Conv2d {
    pub w: Param, // [oc, in_c·k·k]
    pub b: Option<Param>,
    pub geom: Conv2dGeom,
    pub out_c: usize,
    pub pos: LayerPos,
    /// Fusion choice, resolved once at construction ([`im2col_fuses`]).
    fused_im2col: bool,
    layer_id: u64,
    // backward caches
    cols_q: Option<Tensor>,
    w_q: Option<Tensor>,
    batch: usize,
    /// When set, [`Layer::backward`] stores the Gradient-GEMM operands
    /// (error rows, activation patch matrix) for the Fig. 6 harness.
    pub capture: bool,
    pub captured: Option<(Tensor, Tensor)>,
}

impl Conv2d {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        geom: Conv2dGeom,
        out_c: usize,
        pos: LayerPos,
        bias: bool,
        rng: &mut Xoshiro256,
    ) -> Self {
        let fan_in = geom.patch_len();
        let w = init::kaiming_normal(&[out_c, fan_in], fan_in, rng);
        Self {
            w: Param::new(format!("{name}.w"), w, true),
            b: bias.then(|| Param::new(format!("{name}.b"), Tensor::zeros(&[out_c]), false)),
            geom,
            out_c,
            pos,
            fused_im2col: im2col_fuses(&geom),
            layer_id: layer_hash(name),
            cols_q: None,
            w_q: None,
            batch: 0,
            capture: false,
            captured: None,
        }
    }

    pub fn out_shape(&self, n: usize) -> [usize; 4] {
        [n, self.out_c, self.geom.out_h(), self.geom.out_w()]
    }
}

/// `[N·oh·ow, oc]` GEMM-output rows → NCHW.
fn rows_to_nchw(rows: &Tensor, n: usize, oc: usize, oh: usize, ow: usize) -> Tensor {
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    crate::perf::timed(crate::perf::Phase::Pack, || {
        for img in 0..n {
            for s in 0..oh * ow {
                let row = (img * oh * ow + s) * oc;
                for c in 0..oc {
                    out.data[((img * oc) + c) * oh * ow + s] = rows.data[row + c];
                }
            }
        }
    });
    out
}

/// NCHW → `[N·oh·ow, oc]` rows (adjoint of [`rows_to_nchw`]). The result is
/// a step-local temporary, so it leases from the scratch arena. When a
/// quantizer is supplied, quantization is fused into the repack — each
/// element is copied exactly once, so this eliminates the separate
/// full-tensor error-quantize pass for free (bit-identical: elementwise
/// deterministic quantization commutes with the permutation).
fn nchw_to_rows_q(x: &Tensor, quant: Option<NeQuantizer>) -> Tensor {
    let (n, oc, oh, ow) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros_pooled(&[n * oh * ow, oc]);
    // Same telemetry contract as `quantize_batch`: stash the original bits
    // per output row and record (orig, quantized) pairs, `None` — two
    // thread-local reads — unless a layer/role scope is active.
    let mut rec = quant.and_then(|q| crate::telemetry::quant_recorder(q.fmt()));
    let mut orig = vec![0u32; if rec.is_some() { oc } else { 0 }];
    crate::perf::timed(crate::perf::Phase::Pack, || {
        let stash = !orig.is_empty();
        for img in 0..n {
            for s in 0..oh * ow {
                let row = (img * oh * ow + s) * oc;
                match quant {
                    None => {
                        for c in 0..oc {
                            out.data[row + c] = x.data[((img * oc) + c) * oh * ow + s];
                        }
                    }
                    Some(q) => {
                        for c in 0..oc {
                            let v = x.data[((img * oc) + c) * oh * ow + s];
                            if stash {
                                orig[c] = v.to_bits();
                            }
                            out.data[row + c] = q.quantize(v);
                        }
                        if let Some(r) = rec.as_mut() {
                            r.record(&orig, &out.data[row..row + oc]);
                        }
                    }
                }
            }
        }
    });
    if let Some(r) = rec {
        r.commit();
    }
    out
}

fn nchw_to_rows(x: &Tensor) -> Tensor {
    nchw_to_rows_q(x, None)
}

impl Layer for Conv2d {
    fn forward(&mut self, x: Tensor, ctx: &QuantCtx) -> Tensor {
        assert_eq!(x.ndim(), 4, "conv expects NCHW");
        let n = x.shape[0];
        let _tel = crate::telemetry::layer_scope(self.w.name.trim_end_matches(".w"));
        let p = ctx.policy;

        // Stored activation. The fused-vs-pre-lowering quantize choice was
        // made once at construction ([`im2col_fuses`]); both routes are
        // bit-identical (padding zeros are exactly representable and the
        // elementwise quantizer is deterministic, so every replicated copy
        // quantizes to the same bits —
        // `fused_im2col_matches_separate_pass` enforces it).
        let g = self.geom;
        let low_replication = self.fused_im2col;
        let cols_q = match p.plain_act_fmt(GemmRole::Forward, self.pos) {
            Some(fmt) if fmt.is_identity() => im2col(&x, &g),
            Some(fmt) if low_replication => {
                // Role scope so the fused quantize-on-copy records under
                // (layer, fwd) exactly like the separate-pass route.
                let _role = crate::telemetry::role_scope(crate::telemetry::Role::Forward);
                im2col_q(&x, &g, Some(NeQuantizer::new(fmt)))
            }
            Some(_) | None => {
                // Dense kernels and baseline schemes: quantize before
                // lowering (one pass over C·H·W instead of per-copy work
                // on C·k²·oh·ow values).
                let mut x_q = x;
                p.quantize_act(&mut x_q.data, GemmRole::Forward, self.pos);
                im2col(&x_q, &g)
            }
        };

        let prec = p.gemm_for(GemmRole::Forward, self.pos);
        let seed = ctx.gemm_seed(self.layer_id, GemmRole::Forward);
        // W is stored [oc, in_c·k·k] — already the packed-Bᵀ layout for
        // Y = Cols·Wᵀ: no transpose, and the quantized operand comes from
        // the weight tensor's version-keyed pack cache.
        let mut rows = match p.plain_weight_fmt(GemmRole::Forward, self.pos) {
            // Identity formats (fp32 policies): the stored [oc, patch]
            // data IS the packed operand — no copy, no cache entry.
            Some(fmt) if fmt.is_identity() => {
                cols_q.matmul_packed(&self.w.value.data, self.out_c, &prec, seed)
            }
            Some(fmt) => {
                let w_pack = self.w.value.quantized(fmt, RoundMode::NearestEven);
                cols_q.matmul_packed(&w_pack, self.out_c, &prec, seed)
            }
            None => {
                let mut w_q = self.w.value.clone();
                p.quantize_weight(&mut w_q.data, GemmRole::Forward, self.pos);
                let rows = cols_q.matmul_t(&w_q, &prec, seed);
                if ctx.train {
                    self.w_q = Some(w_q);
                } else {
                    w_q.recycle();
                }
                rows
            }
        };
        if let Some(b) = &self.b {
            rows.add_row(&b.value.data);
        }
        let (oh, ow) = (self.geom.out_h(), self.geom.out_w());
        let y = rows_to_nchw(&rows, n, self.out_c, oh, ow);
        rows.recycle();
        if ctx.train {
            self.cols_q = Some(cols_q);
            self.batch = n;
        } else {
            // Eval drops the cache immediately — return the big patch
            // matrix to the arena so eval loops re-lease instead of
            // re-allocating every batch.
            cols_q.recycle();
        }
        y
    }

    fn backward(&mut self, dy: Tensor, ctx: &QuantCtx) -> Tensor {
        let _tel = crate::telemetry::layer_scope(self.w.name.trim_end_matches(".w"));
        let p = ctx.policy;
        let cols_q = self.cols_q.take().expect("backward before forward");
        let n = self.batch;
        assert_eq!(dy.shape, self.out_shape(n).to_vec());

        // Bias gradient in full precision, straight from the raw NCHW
        // error. Channel-outer loop order keeps every read contiguous (one
        // `[oh·ow]` plane at a time) while each channel still accumulates
        // its terms in the exact (image, site) order the old rows-matrix
        // `sum_rows` used, from a zeroed scratch start — bit-identical.
        if let Some(b) = &mut self.b {
            let (oc, ohw) = (self.out_c, dy.shape[2] * dy.shape[3]);
            let mut sums = scratch::take(oc);
            for (c, acc) in sums.iter_mut().enumerate() {
                for img in 0..n {
                    let plane = (img * oc + c) * ohw;
                    for &v in &dy.data[plane..plane + ohw] {
                        *acc += v;
                    }
                }
            }
            for (g, v) in b.grad.data.iter_mut().zip(&sums) {
                *g += v;
            }
            scratch::recycle(sums);
        }

        // Error rows [N·oh·ow, oc]: quantization fused into the repack —
        // each element is copied exactly once, so the old separate
        // full-tensor quantize pass disappears entirely.
        let err = match p.plain_err_fmt(GemmRole::Backward, self.pos) {
            Some(fmt) => {
                let _role = crate::telemetry::role_scope(crate::telemetry::Role::Backward);
                let q = (!fmt.is_identity()).then(|| NeQuantizer::new(fmt));
                nchw_to_rows_q(&dy, q)
            }
            None => {
                let mut err = nchw_to_rows(&dy);
                p.quantize_err(
                    &mut err.data,
                    GemmRole::Backward,
                    self.pos,
                    ctx.gemm_seed(self.layer_id, GemmRole::Backward) ^ 0xE44,
                );
                err
            }
        };
        dy.recycle();

        if self.capture {
            self.captured = Some((err.clone(), cols_q.clone()));
        }

        // Gradient GEMM: dW = errᵀ · cols, K = N·oh·ow. The transposed
        // error operand is a step-local temporary → scratch arena.
        let prec_g = p.gemm_for(GemmRole::Gradient, self.pos);
        let err_t = err.t_pooled();
        let dw = err_t.matmul(
            &cols_q,
            &prec_g,
            ctx.gemm_seed(self.layer_id, GemmRole::Gradient),
        );
        err_t.recycle();
        self.w.grad.add_assign(&dw);
        dw.recycle();

        // Backward GEMM: dCols = err · Wq, then col2im scatter. The weight
        // operand is the stored (Forward-format) quantized copy, served
        // from the cache in its transposed packed form.
        let prec_b = p.gemm_for(GemmRole::Backward, self.pos);
        let seed_b = ctx.gemm_seed(self.layer_id, GemmRole::Backward);
        let dcols = match p.plain_weight_fmt(GemmRole::Forward, self.pos) {
            // Identity formats: the plain transpose cache suffices.
            Some(fmt) if fmt.is_identity() => {
                let w_pack = self.w.value.packed_t();
                err.matmul_packed(&w_pack, self.geom.patch_len(), &prec_b, seed_b)
            }
            Some(fmt) => {
                let w_pack = self.w.value.quantized_t(fmt, RoundMode::NearestEven);
                err.matmul_packed(&w_pack, self.geom.patch_len(), &prec_b, seed_b)
            }
            None => {
                let w_q = self.w_q.take().expect("backward before forward");
                let dcols = err.matmul(&w_q, &prec_b, seed_b);
                w_q.recycle();
                dcols
            }
        };
        let dx = col2im(&dcols, &self.geom, n);
        // Everything whose lifetime ended this step goes back to the arena.
        dcols.recycle();
        err.recycle();
        cols_q.recycle();
        dx
    }

    fn visit_params(&mut self, f: &mut dyn FnMut(&mut Param)) {
        f(&mut self.w);
        if let Some(b) = &mut self.b {
            f(b);
        }
    }

    fn name(&self) -> String {
        self.w.name.trim_end_matches(".w").to_string()
    }

    fn macs_per_example(&self) -> u64 {
        (self.geom.out_h() * self.geom.out_w() * self.out_c * self.geom.patch_len()) as u64
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn invalidate_backward_state(&mut self) {
        // Return the cached patch matrix / quantized weight to the arena
        // and zero `batch`, so a mispaired backward hits the
        // "backward before forward" expect instead of consuming operands
        // from the previous training batch.
        if let Some(t) = self.cols_q.take() {
            t.recycle();
        }
        if let Some(t) = self.w_q.take() {
            t.recycle();
        }
        self.batch = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::PrecisionPolicy;

    fn small_geom() -> Conv2dGeom {
        Conv2dGeom {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            k: 3,
            stride: 1,
            pad: 1,
        }
    }

    #[test]
    fn forward_shape_and_layout() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut c = Conv2d::new("c1", small_geom(), 4, LayerPos::Middle, true, &mut rng);
        let x = Tensor::zeros(&[3, 2, 5, 5]);
        let y = c.forward(x, &ctx);
        assert_eq!(y.shape, vec![3, 4, 5, 5]);
    }

    #[test]
    fn rows_nchw_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let x = Tensor::from_vec(
            &[2, 3, 4, 4],
            (0..96).map(|_| rng.uniform(-1.0, 1.0)).collect(),
        );
        let rows = nchw_to_rows(&x);
        assert_eq!(rows.shape, vec![32, 3]);
        let back = rows_to_nchw(&rows, 2, 3, 4, 4);
        assert_eq!(back, x);
    }

    #[test]
    fn conv_gradcheck_fp32() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let g = Conv2dGeom {
            in_c: 1,
            in_h: 4,
            in_w: 4,
            k: 3,
            stride: 1,
            pad: 1,
        };
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut c = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut rng);
        let x = Tensor::from_vec(&[1, 1, 4, 4], (0..16).map(|i| 0.1 * i as f32 - 0.8).collect());
        let dy_data: Vec<f32> = (0..32).map(|i| ((i * 13 % 7) as f32 - 3.0) / 10.0).collect();
        let dy = Tensor::from_vec(&[1, 2, 4, 4], dy_data);

        c.forward(x.clone(), &ctx);
        let dx = c.backward(dy.clone(), &ctx);

        // finite differences on x
        let eps = 1e-2f32;
        for i in (0..16).step_by(3) {
            let mut xp = x.clone();
            xp.data[i] += eps;
            let mut xm = x.clone();
            xm.data[i] -= eps;
            let mut cp = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            let mut cm = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            let yp = cp.forward(xp, &ctx);
            let ym = cm.forward(xm, &ctx);
            let fp: f32 = yp.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let fm: f32 = ym.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - dx.data[i]).abs() < 1e-2,
                "dx[{i}]: numeric {num} vs analytic {}",
                dx.data[i]
            );
        }

        // finite differences on w (a few entries)
        let dw = c.w.grad.clone();
        for i in (0..c.w.value.len()).step_by(5) {
            let mut cp = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            let mut cm = Conv2d::new("c", g, 2, LayerPos::Middle, true, &mut Xoshiro256::seed_from_u64(3));
            cp.w.value.data[i] += eps;
            cm.w.value.data[i] -= eps;
            let yp = cp.forward(x.clone(), &ctx);
            let ym = cm.forward(x.clone(), &ctx);
            let fp: f32 = yp.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let fm: f32 = ym.data.iter().zip(&dy.data).map(|(a, b)| a * b).sum();
            let num = (fp - fm) / (2.0 * eps);
            assert!(
                (num - dw.data[i]).abs() < 2e-2,
                "dw[{i}]: numeric {num} vs analytic {}",
                dw.data[i]
            );
        }
    }

    #[test]
    fn fused_cached_dataflow_matches_explicit_two_pass() {
        // The quantize-on-pack pipeline (fused im2col / fused error repack /
        // cached quantized weight packs) vs the pre-refactor explicit
        // dataflow (quantize full tensors separately, clone the weight per
        // GEMM): every output, gradient and stored operand bit-identical.
        for policy in [PrecisionPolicy::fp8_paper(), PrecisionPolicy::fp32()] {
            let ctx = QuantCtx::new(&policy, 3, true);
            let g = small_geom();
            let pos = LayerPos::Middle;
            let mut rng = Xoshiro256::seed_from_u64(8);
            let mut conv = Conv2d::new("c1", g, 4, pos, true, &mut rng);
            let n = 2;
            let x = Tensor::from_vec(
                &[n, 2, 5, 5],
                (0..n * 2 * 5 * 5).map(|i| (i as f32 - 25.0) * 0.037).collect(),
            );
            let dy = Tensor::from_vec(
                &[n, 4, 5, 5],
                (0..n * 4 * 5 * 5)
                    .map(|i| ((i * 7 % 11) as f32 - 5.0) * 0.21)
                    .collect(),
            );

            let y = conv.forward(x.clone(), &ctx);
            let dx = conv.backward(dy.clone(), &ctx);
            let id = layer_hash("c1");

            // --- the explicit (pre-refactor) dataflow ---
            let p = &policy;
            let mut x_q = x;
            p.quantize_act(&mut x_q.data, GemmRole::Forward, pos);
            let cols_q = im2col(&x_q, &g);
            let mut w_q = conv.w.value.clone();
            p.quantize_weight(&mut w_q.data, GemmRole::Forward, pos);
            let prec = p.gemm_for(GemmRole::Forward, pos);
            let mut rows = cols_q.matmul_t(&w_q, &prec, ctx.gemm_seed(id, GemmRole::Forward));
            rows.add_row(&conv.b.as_ref().unwrap().value.data);
            let y_ref = rows_to_nchw(&rows, n, 4, 5, 5);
            assert_eq!(y, y_ref, "{} forward", policy.name);

            let mut err = nchw_to_rows(&dy);
            let bias_ref = err.sum_rows();
            p.quantize_err(
                &mut err.data,
                GemmRole::Backward,
                pos,
                ctx.gemm_seed(id, GemmRole::Backward) ^ 0xE44,
            );
            let prec_g = p.gemm_for(GemmRole::Gradient, pos);
            let dw_ref = err
                .t()
                .matmul(&cols_q, &prec_g, ctx.gemm_seed(id, GemmRole::Gradient));
            assert_eq!(conv.w.grad, dw_ref, "{} dW", policy.name);
            assert_eq!(
                conv.b.as_ref().unwrap().grad.data,
                bias_ref,
                "{} db",
                policy.name
            );
            let prec_b = p.gemm_for(GemmRole::Backward, pos);
            let dcols = err.matmul(&w_q, &prec_b, ctx.gemm_seed(id, GemmRole::Backward));
            let dx_ref = col2im(&dcols, &g, n);
            assert_eq!(dx, dx_ref, "{} dX", policy.name);
        }
    }

    #[test]
    fn low_replication_fused_im2col_path_matches_explicit() {
        // 1×1 kernel (replication factor 1): the layer takes the fused
        // quantize-on-lower route; outputs must equal the explicit
        // quantize-then-lower dataflow bitwise.
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 1, true);
        let g = Conv2dGeom {
            in_c: 3,
            in_h: 4,
            in_w: 4,
            k: 1,
            stride: 1,
            pad: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut conv = Conv2d::new("cp", g, 5, LayerPos::Middle, false, &mut rng);
        let x = Tensor::from_vec(
            &[2, 3, 4, 4],
            (0..96).map(|i| (i as f32 - 48.0) * 0.083).collect(),
        );
        let y = conv.forward(x.clone(), &ctx);

        let mut x_q = x;
        policy.quantize_act(&mut x_q.data, GemmRole::Forward, LayerPos::Middle);
        let cols = im2col(&x_q, &g);
        let mut w_q = conv.w.value.clone();
        policy.quantize_weight(&mut w_q.data, GemmRole::Forward, LayerPos::Middle);
        let prec = policy.gemm_for(GemmRole::Forward, LayerPos::Middle);
        let rows = cols.matmul_t(
            &w_q,
            &prec,
            ctx.gemm_seed(layer_hash("cp"), GemmRole::Forward),
        );
        let y_ref = rows_to_nchw(&rows, 2, 5, 4, 4);
        assert_eq!(y, y_ref);
    }

    #[test]
    fn fused_conv_passes_report_telemetry() {
        use crate::telemetry::{self, Role};
        // The fused quantize-on-copy routes (im2col_q on the 1×1 forward,
        // the NCHW→rows error repack on backward) must show up in the
        // per-(layer, role) counters like any batch-quantize pass.
        telemetry::reset();
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 1, true);
        let g = Conv2dGeom {
            in_c: 3,
            in_h: 4,
            in_w: 4,
            k: 1,
            stride: 1,
            pad: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut conv = Conv2d::new("ct", g, 5, LayerPos::Middle, false, &mut rng);
        let x = Tensor::from_vec(
            &[2, 3, 4, 4],
            (0..96).map(|i| (i as f32 - 48.0) * 0.083).collect(),
        );
        let dy = Tensor::from_vec(
            &[2, 5, 4, 4],
            (0..160).map(|i| ((i * 7 % 11) as f32 - 5.0) * 0.21).collect(),
        );
        conv.forward(x, &ctx);
        conv.backward(dy, &ctx);
        let snap = telemetry::snapshot();
        let elems = |role: Role| {
            snap.iter()
                .find(|(name, r, _)| name == "ct" && *r == role)
                .map(|(_, _, s)| s.elems)
        };
        // Forward im2col_q: 2 images × 16 sites × patch length 3.
        assert_eq!(elems(Role::Forward), Some(96));
        // Backward error repack: 2 images × 16 sites × 5 out channels.
        assert_eq!(elems(Role::Backward), Some(160));
        telemetry::reset();
    }

    #[test]
    fn optimizer_axpys_report_update_telemetry() {
        use crate::optim::{Optimizer, Sgd};
        use crate::telemetry::{self, Role};
        // The per-step SGD AXPYs quantize into the update format; their
        // counters must land under (param, upd) at update time — the gap
        // docs/observability.md used to caveat. Weight (decay) takes the
        // three-AXPY path: 3 quantize passes × len; bias (no decay) skips
        // the L2 fold: 2 × len.
        telemetry::reset();
        let policy = PrecisionPolicy::fp8_paper(); // fp16 SR updates
        let g = Conv2dGeom {
            in_c: 3,
            in_h: 4,
            in_w: 4,
            k: 1,
            stride: 1,
            pad: 0,
        };
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut conv = Conv2d::new("ct", g, 5, LayerPos::Middle, true, &mut rng);
        conv.w.grad.data.fill(0.01 * policy.loss_scale);
        conv.b.as_mut().unwrap().grad.data.fill(0.01 * policy.loss_scale);
        let mut opt = Sgd::new(0.9, 1e-4, 3);
        opt.step(&mut conv, &policy, 0.1, 1);
        let snap = telemetry::snapshot();
        let upd = |param: &str| {
            snap.iter()
                .find(|(name, r, _)| name == param && *r == Role::Update)
                .map(|(_, _, s)| s.elems)
        };
        assert_eq!(upd("ct.w"), Some(3 * 15)); // axpy(wd) + xpby + axpy(-lr)
        assert_eq!(upd("ct.b"), Some(2 * 5)); // no decay: xpby + axpy(-lr)
        telemetry::reset();
    }

    #[test]
    fn macs_count() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let c = Conv2d::new("c", small_geom(), 4, LayerPos::Middle, false, &mut rng);
        // 5·5 output sites × 4 out channels × 18 patch = 1800 MACs.
        assert_eq!(c.macs_per_example(), 1800);
    }

    #[test]
    fn strided_conv_shapes() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let g = Conv2dGeom {
            in_c: 3,
            in_h: 8,
            in_w: 8,
            k: 3,
            stride: 2,
            pad: 1,
        };
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut c = Conv2d::new("c", g, 6, LayerPos::Middle, false, &mut rng);
        let y = c.forward(Tensor::zeros(&[2, 3, 8, 8]), &ctx);
        assert_eq!(y.shape, vec![2, 6, 4, 4]);
        let dx = c.backward(Tensor::zeros(&[2, 6, 4, 4]), &ctx);
        assert_eq!(dx.shape, vec![2, 3, 8, 8]);
    }
}
