//! AlexNet (Krizhevsky et al. [15], Appendix A): 5 Conv + 3 FC layers,
//! 1K-way Softmax. Scaled per DESIGN.md §7 to 32×32 inputs / 100 classes:
//! the 5-conv + pool pattern and the large FC head (the part that makes
//! AlexNet the paper's FC-heavy, Gradient-GEMM-stressing benchmark) are
//! preserved; channel widths reduced ~4–8×.

use crate::nn::act::Relu;
use crate::nn::conv::Conv2d;
use crate::nn::linear::Linear;
use crate::nn::pool::MaxPool2d;
use crate::nn::quant::LayerPos;
use crate::nn::{Flatten, Layer, Sequential};
use crate::numerics::Xoshiro256;
use crate::tensor::Conv2dGeom;

pub fn build(rng: &mut Xoshiro256) -> Sequential {
    let g3 = |in_c, hw| Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        k: 3,
        stride: 1,
        pad: 1,
    };
    let layers: Vec<Box<dyn Layer>> = vec![
        // conv1 3→24 @32, pool → 16
        Box::new(Conv2d::new("conv1", g3(3, 32), 24, LayerPos::First, true, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2d::new(2, 2)),
        // conv2 24→48 @16, pool → 8
        Box::new(Conv2d::new("conv2", g3(24, 16), 48, LayerPos::Middle, true, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2d::new(2, 2)),
        // conv3-5: 48→64→64→48 @8, pool → 4
        Box::new(Conv2d::new("conv3", g3(48, 8), 64, LayerPos::Middle, true, rng)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new("conv4", g3(64, 8), 64, LayerPos::Middle, true, rng)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new("conv5", g3(64, 8), 48, LayerPos::Middle, true, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2d::new(2, 2)),
        Box::new(Flatten::new()),
        // FC head: 768 → 256 → 256 → 100
        Box::new(Linear::new("fc6", 48 * 4 * 4, 256, LayerPos::Middle, rng)),
        Box::new(Relu::new()),
        Box::new(Linear::new("fc7", 256, 256, LayerPos::Middle, rng)),
        Box::new(Relu::new()),
        Box::new(Linear::new("fc8", 256, 10, LayerPos::Last, rng)),
    ];
    Sequential::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn five_conv_three_fc() {
        let mut m = build(&mut Xoshiro256::seed_from_u64(0));
        let mut conv_params = 0;
        let mut fc_params = 0;
        m.visit_params(&mut |p| {
            if p.name.starts_with("conv") {
                conv_params += 1;
            } else if p.name.starts_with("fc") {
                fc_params += 1;
            }
        });
        assert_eq!(conv_params, 10); // 5 conv × (w,b)
        assert_eq!(fc_params, 6); // 3 fc × (w,b)
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, false);
        let y = m.forward(Tensor::zeros(&[2, 3, 32, 32]), &ctx);
        assert_eq!(y.shape, vec![2, 10]);
    }
}
