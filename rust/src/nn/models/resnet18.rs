//! ResNet18 (He et al. [9], Appendix A: 8 basic blocks / 16 conv layers +
//! stem + FC). Scaled per DESIGN.md §7 to 32×32 inputs / 100 classes: the
//! exact ImageNet stage pattern — 4 stages × 2 basic blocks with channel
//! doubling and stride-2 stage transitions — at widths 16/32/64/128.

use crate::nn::linear::Linear;
use crate::nn::models::{basic_block, conv_bn_relu};
use crate::nn::pool::GlobalAvgPool;
use crate::nn::quant::LayerPos;
use crate::nn::{Layer, Sequential};
use crate::numerics::Xoshiro256;

pub fn build(rng: &mut Xoshiro256) -> Sequential {
    let mut layers: Vec<Box<dyn Layer>> = Vec::new();
    layers.extend(conv_bn_relu("stem", 3, 32, 16, 3, 1, 1, LayerPos::First, rng));
    let mut c = 16;
    let mut hw = 32;
    for (s, &width) in [16usize, 32, 64, 128].iter().enumerate() {
        for b in 0..2 {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let (block, out_hw) = basic_block(&format!("s{s}b{b}"), c, hw, width, stride, rng);
            layers.push(Box::new(block));
            c = width;
            hw = out_hw;
        }
    }
    layers.push(Box::new(GlobalAvgPool::new()));
    layers.push(Box::new(Linear::new("fc", 128, 10, LayerPos::Last, rng)));
    Sequential::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn eight_blocks_and_shapes() {
        let mut m = build(&mut Xoshiro256::seed_from_u64(0));
        // stem(conv+bn) + 8 blocks + fc: count conv weight params = 1 stem +
        // 16 block convs + 3 projections = 20.
        let mut convs = 0;
        m.visit_params(&mut |p| {
            if p.name.ends_with(".w") && !p.name.starts_with("fc") {
                convs += 1;
            }
        });
        assert_eq!(convs, 20);
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, false);
        let y = m.forward(Tensor::zeros(&[2, 3, 32, 32]), &ctx);
        assert_eq!(y.shape, vec![2, 10]);
    }
}
