//! BN50-DNN (van den Berg et al. [19], Appendix A): a speech-recognition
//! DNN of 6 FC layers (440×1024, 4×1024×1024, 1024×5999) over acoustic
//! frames. Scaled per DESIGN.md §7 to 440→256→256→256→256→120 with the
//! same 440-dim input and the FC-only topology; BN50's senone count is
//! scaled 5999→120 classes. ReLU activations between layers (the modern
//! equivalent of the reference's sigmoids; keeps the GEMM precision study
//! identical).

use crate::nn::act::Relu;
use crate::nn::linear::Linear;
use crate::nn::quant::LayerPos;
use crate::nn::{Layer, Sequential};
use crate::numerics::Xoshiro256;

pub const INPUT_DIM: usize = 440;
pub const HIDDEN: usize = 256;
pub const CLASSES: usize = 30;

pub fn build(rng: &mut Xoshiro256) -> Sequential {
    let mut layers: Vec<Box<dyn Layer>> = vec![
        Box::new(Linear::new("fc1", INPUT_DIM, HIDDEN, LayerPos::First, rng)),
        Box::new(Relu::new()),
    ];
    for i in 2..=5 {
        layers.push(Box::new(Linear::new(
            &format!("fc{i}"),
            HIDDEN,
            HIDDEN,
            LayerPos::Middle,
            rng,
        )));
        layers.push(Box::new(Relu::new()));
    }
    layers.push(Box::new(Linear::new("fc6", HIDDEN, CLASSES, LayerPos::Last, rng)));
    Sequential::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn six_fc_layers() {
        let mut m = build(&mut Xoshiro256::seed_from_u64(0));
        let expect = (440 * 256 + 256) + 4 * (256 * 256 + 256) + (256 * 30 + 30);
        assert_eq!(m.num_params(), expect);
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 0, true);
        let y = m.forward(Tensor::zeros(&[8, 440]), &ctx);
        assert_eq!(y.shape, vec![8, 30]);
    }
}
