//! The paper's six benchmark networks (Appendix A), architecture-faithful
//! but width/resolution-scaled per DESIGN.md §7: same layer types, block
//! structure, depth pattern and BN placement; input resolution 32×32 and
//! channel widths reduced so the evaluation suite trains in CPU-emulation
//! time. The dot-product lengths (`in_c·k·k` after lowering, batch·H·W for
//! Gradient GEMM) stay in the hundreds-to-thousands regime that Figs. 3/6
//! study, which is what the swamping phenomena depend on.

pub mod alexnet;
pub mod bn50_dnn;
pub mod cifar_cnn;
pub mod cifar_resnet;
pub mod resnet18;
pub mod resnet50;

use super::act::Relu;
use super::conv::Conv2d;
use super::norm::BatchNorm;
use super::quant::LayerPos;
use super::{Layer, Residual, Sequential};
use crate::numerics::Xoshiro256;
use crate::tensor::Conv2dGeom;

/// What kind of input tensor a model consumes (drives the synthetic data
/// generators in `data/`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputKind {
    /// NCHW image batch.
    Image {
        c: usize,
        h: usize,
        w: usize,
    },
    /// [N, features] frame batch (BN50 speech).
    Vector { dim: usize },
}

impl InputKind {
    pub fn shape(&self, n: usize) -> Vec<usize> {
        match *self {
            InputKind::Image { c, h, w } => vec![n, c, h, w],
            InputKind::Vector { dim } => vec![n, dim],
        }
    }
}

/// The model zoo identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    CifarCnn,
    CifarResnet,
    Bn50Dnn,
    AlexNet,
    ResNet18,
    ResNet50,
}

impl ModelKind {
    pub const ALL: [ModelKind; 6] = [
        ModelKind::CifarCnn,
        ModelKind::CifarResnet,
        ModelKind::Bn50Dnn,
        ModelKind::AlexNet,
        ModelKind::ResNet18,
        ModelKind::ResNet50,
    ];

    pub fn id(self) -> &'static str {
        match self {
            ModelKind::CifarCnn => "cifar_cnn",
            ModelKind::CifarResnet => "cifar_resnet",
            ModelKind::Bn50Dnn => "bn50_dnn",
            ModelKind::AlexNet => "alexnet",
            ModelKind::ResNet18 => "resnet18",
            ModelKind::ResNet50 => "resnet50",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.into_iter().find(|m| m.id() == s)
    }

    pub fn input(self) -> InputKind {
        match self {
            ModelKind::Bn50Dnn => InputKind::Vector { dim: 440 },
            _ => InputKind::Image { c: 3, h: 32, w: 32 },
        }
    }

    /// Class count. CIFAR-scale sets keep their 10 classes; the
    /// ImageNet-like and BN50-like synthetic sets are scaled to 10 and 30
    /// classes respectively (from 1000 / 5999) so the committed few-dozen-
    /// step runs see enough examples per class for policy contrasts to be
    /// meaningful (DESIGN.md §7 — class count is orthogonal to the
    /// numerics under study).
    pub fn classes(self) -> usize {
        match self {
            ModelKind::Bn50Dnn => 30,
            _ => 10,
        }
    }

    /// Build the network with deterministic initialization.
    pub fn build(self, seed: u64) -> Sequential {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        match self {
            ModelKind::CifarCnn => cifar_cnn::build(&mut rng),
            ModelKind::CifarResnet => cifar_resnet::build(&mut rng),
            ModelKind::Bn50Dnn => bn50_dnn::build(&mut rng),
            ModelKind::AlexNet => alexnet::build(&mut rng),
            ModelKind::ResNet18 => resnet18::build(&mut rng),
            ModelKind::ResNet50 => resnet50::build(&mut rng),
        }
    }
}

/// conv(k×k, stride, pad) → BN → ReLU, the standard ResNet unit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_bn_relu(
    name: &str,
    in_c: usize,
    hw: usize,
    out_c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    pos: LayerPos,
    rng: &mut Xoshiro256,
) -> Vec<Box<dyn Layer>> {
    let geom = Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        k,
        stride,
        pad,
    };
    vec![
        Box::new(Conv2d::new(name, geom, out_c, pos, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn"), out_c)),
        Box::new(Relu::new()),
    ]
}

/// A basic (3×3, 3×3) residual block; returns the block and the output
/// spatial size.
pub(crate) fn basic_block(
    name: &str,
    in_c: usize,
    hw: usize,
    out_c: usize,
    stride: usize,
    rng: &mut Xoshiro256,
) -> (Residual, usize) {
    let out_hw = (hw + 2 - 3) / stride + 1;
    let g1 = Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        k: 3,
        stride,
        pad: 1,
    };
    let g2 = Conv2dGeom {
        in_c: out_c,
        in_h: out_hw,
        in_w: out_hw,
        k: 3,
        stride: 1,
        pad: 1,
    };
    let main = Sequential::new(vec![
        Box::new(Conv2d::new(&format!("{name}.c1"), g1, out_c, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn1"), out_c)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new(&format!("{name}.c2"), g2, out_c, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn2"), out_c)),
    ]);
    let shortcut = if stride != 1 || in_c != out_c {
        let gp = Conv2dGeom {
            in_c,
            in_h: hw,
            in_w: hw,
            k: 1,
            stride,
            pad: 0,
        };
        Some(Sequential::new(vec![
            Box::new(Conv2d::new(&format!("{name}.proj"), gp, out_c, LayerPos::Middle, false, rng)),
            Box::new(BatchNorm::new_2d(&format!("{name}.bnp"), out_c)),
        ]))
    } else {
        None
    };
    (Residual::new(main, shortcut), out_hw)
}

/// A bottleneck (1×1 reduce, 3×3, 1×1 expand) residual block with
/// expansion factor `exp`; returns the block and output spatial size.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bottleneck_block(
    name: &str,
    in_c: usize,
    hw: usize,
    width: usize,
    exp: usize,
    stride: usize,
    rng: &mut Xoshiro256,
) -> (Residual, usize, usize) {
    let out_c = width * exp;
    let out_hw = (hw + 2 - 3) / stride + 1;
    let g1 = Conv2dGeom { in_c, in_h: hw, in_w: hw, k: 1, stride: 1, pad: 0 };
    let g2 = Conv2dGeom { in_c: width, in_h: hw, in_w: hw, k: 3, stride, pad: 1 };
    let g3 = Conv2dGeom { in_c: width, in_h: out_hw, in_w: out_hw, k: 1, stride: 1, pad: 0 };
    let main = Sequential::new(vec![
        Box::new(Conv2d::new(&format!("{name}.c1"), g1, width, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn1"), width)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new(&format!("{name}.c2"), g2, width, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn2"), width)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new(&format!("{name}.c3"), g3, out_c, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn3"), out_c)),
    ]);
    let shortcut = if stride != 1 || in_c != out_c {
        let gp = Conv2dGeom { in_c, in_h: hw, in_w: hw, k: 1, stride, pad: 0 };
        Some(Sequential::new(vec![
            Box::new(Conv2d::new(&format!("{name}.proj"), gp, out_c, LayerPos::Middle, false, rng)),
            Box::new(BatchNorm::new_2d(&format!("{name}.bnp"), out_c)),
        ]))
    } else {
        None
    };
    (Residual::new(main, shortcut), out_c, out_hw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn all_models_build_and_forward() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, false);
        for kind in ModelKind::ALL {
            let mut m = kind.build(7);
            let x = Tensor::zeros(&kind.input().shape(2));
            let y = m.forward(x, &ctx);
            assert_eq!(
                y.shape,
                vec![2, kind.classes()],
                "{} output shape",
                kind.id()
            );
            assert!(m.num_params() > 1000, "{} too small", kind.id());
        }
    }

    #[test]
    fn all_models_backward_under_paper_policy() {
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 1, true);
        for kind in [ModelKind::CifarCnn, ModelKind::Bn50Dnn] {
            let mut m = kind.build(7);
            let x = Tensor::zeros(&kind.input().shape(2));
            let y = m.forward(x, &ctx);
            let dy = Tensor::full(&y.shape, 0.01);
            let dx = m.backward(dy, &ctx);
            assert_eq!(dx.shape, kind.input().shape(2), "{}", kind.id());
        }
    }

    #[test]
    fn model_state_dict_round_trips_through_residual_blocks() {
        use crate::state::{StateDict, StateMap};
        // CifarResnet exercises the full recursion: Sequential → Residual
        // (main + projection shortcut) → Conv/BN, including BN running
        // stats behind two levels of containers.
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut m = ModelKind::CifarResnet.build(3);
        let x = Tensor::from_vec(
            &[2, 3, 32, 32],
            (0..2 * 3 * 32 * 32).map(|i| (i % 7) as f32 * 0.1).collect(),
        );
        m.forward(x, &ctx); // move BN running stats off their init values
        let mut map = StateMap::new();
        m.save_state("model", &mut map);
        let n_params = {
            let mut n = 0;
            m.visit_params(&mut |_| n += 1);
            n
        };
        assert!(map.len() > n_params, "extra state (BN stats) must be saved");
        let mut fresh = ModelKind::CifarResnet.build(99);
        fresh.load_state("model", &map).unwrap();
        let mut map2 = StateMap::new();
        fresh.save_state("model", &mut map2);
        assert_eq!(map, map2, "restored model must serialize bit-identically");
        // Strictness: a truncated map is rejected.
        let empty = StateMap::new();
        assert!(ModelKind::CifarResnet.build(0).load_state("model", &empty).is_err());
    }

    #[test]
    fn kind_ids_roundtrip() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::parse(kind.id()), Some(kind));
        }
        assert_eq!(ModelKind::parse("bogus"), None);
    }

    #[test]
    fn model_size_ordering_matches_table1() {
        // Table 1's model sizes are ordered CIFAR-CNN < CIFAR-ResNet <
        // ResNet18 < ResNet50 < AlexNet (FC-heavy); scaled versions must
        // preserve CNN < ResNet orderings at least.
        let n = |k: ModelKind| k.build(0).num_params();
        assert!(n(ModelKind::CifarCnn) < n(ModelKind::CifarResnet));
        assert!(n(ModelKind::CifarResnet) < n(ModelKind::ResNet18));
        assert!(n(ModelKind::ResNet18) < n(ModelKind::ResNet50));
    }

    #[test]
    fn basic_block_shapes() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (mut b, out_hw) = basic_block("t", 8, 16, 16, 2, &mut rng);
        assert_eq!(out_hw, 8);
        let y = b.forward(Tensor::zeros(&[1, 8, 16, 16]), &ctx);
        assert_eq!(y.shape, vec![1, 16, 8, 8]);
        let dx = b.backward(Tensor::zeros(&[1, 16, 8, 8]), &ctx);
        assert_eq!(dx.shape, vec![1, 8, 16, 16]);
    }

    #[test]
    fn bottleneck_block_shapes() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (mut b, out_c, out_hw) = bottleneck_block("t", 16, 8, 8, 4, 1, &mut rng);
        assert_eq!((out_c, out_hw), (32, 8));
        let y = b.forward(Tensor::zeros(&[1, 16, 8, 8]), &ctx);
        assert_eq!(y.shape, vec![1, 32, 8, 8]);
    }
}
