//! The paper's six benchmark networks (Appendix A), architecture-faithful
//! but width/resolution-scaled per DESIGN.md §7: same layer types, block
//! structure, depth pattern and BN placement; input resolution 32×32 and
//! channel widths reduced so the evaluation suite trains in CPU-emulation
//! time. The dot-product lengths (`in_c·k·k` after lowering, batch·H·W for
//! Gradient GEMM) stay in the hundreds-to-thousands regime that Figs. 3/6
//! study, which is what the swamping phenomena depend on.
//!
//! Construction now goes through [`crate::nn::spec::ModelSpec`] — the six
//! networks are **named preset specs** (`ModelSpec::preset("cifar_cnn")`,
//! …). The hand-built `build` functions in the submodules remain as the
//! normative references for the preset bridge: `rust/tests/spec_bridge.rs`
//! asserts that spec-built presets are bit-identical to them (same RNG
//! draw order, same layer names, hence same SR streams and `StateDict`
//! keys — old checkpoints keep loading).

pub mod alexnet;
pub mod bn50_dnn;
pub mod cifar_cnn;
pub mod cifar_resnet;
pub mod resnet18;
pub mod resnet50;

use super::act::Relu;
use super::conv::Conv2d;
use super::norm::BatchNorm;
use super::quant::LayerPos;
use super::{Layer, Residual, Sequential};
use crate::numerics::Xoshiro256;
use crate::tensor::Conv2dGeom;

/// What kind of input tensor a model consumes (drives the synthetic data
/// generators in `data/`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InputKind {
    /// NCHW image batch.
    Image {
        c: usize,
        h: usize,
        w: usize,
    },
    /// [N, features] frame batch (BN50 speech).
    Vector { dim: usize },
}

impl InputKind {
    pub fn shape(&self, n: usize) -> Vec<usize> {
        match *self {
            InputKind::Image { c, h, w } => vec![n, c, h, w],
            InputKind::Vector { dim } => vec![n, dim],
        }
    }
}

/// The hand-built reference builders, keyed by preset id — the comparison
/// side of the spec bridge (`rust/tests/spec_bridge.rs`).
pub const REFERENCE_BUILDERS: [(&str, fn(&mut Xoshiro256) -> Sequential); 6] = [
    ("cifar_cnn", cifar_cnn::build),
    ("cifar_resnet", cifar_resnet::build),
    ("bn50_dnn", bn50_dnn::build),
    ("alexnet", alexnet::build),
    ("resnet18", resnet18::build),
    ("resnet50", resnet50::build),
];

/// Build the hand-built reference model for `preset_id` with the same
/// seeding convention as `ModelSpec::build`.
pub fn reference_build(preset_id: &str, seed: u64) -> Option<Sequential> {
    REFERENCE_BUILDERS
        .iter()
        .find(|(id, _)| *id == preset_id)
        .map(|(_, build)| build(&mut Xoshiro256::seed_from_u64(seed)))
}

/// conv(k×k, stride, pad) → BN → ReLU, the standard ResNet unit.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_bn_relu(
    name: &str,
    in_c: usize,
    hw: usize,
    out_c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    pos: LayerPos,
    rng: &mut Xoshiro256,
) -> Vec<Box<dyn Layer>> {
    let geom = Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        k,
        stride,
        pad,
    };
    vec![
        Box::new(Conv2d::new(name, geom, out_c, pos, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn"), out_c)),
        Box::new(Relu::new()),
    ]
}

/// A basic (3×3, 3×3) residual block; returns the block and the output
/// spatial size.
pub(crate) fn basic_block(
    name: &str,
    in_c: usize,
    hw: usize,
    out_c: usize,
    stride: usize,
    rng: &mut Xoshiro256,
) -> (Residual, usize) {
    let out_hw = (hw + 2 - 3) / stride + 1;
    let g1 = Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        k: 3,
        stride,
        pad: 1,
    };
    let g2 = Conv2dGeom {
        in_c: out_c,
        in_h: out_hw,
        in_w: out_hw,
        k: 3,
        stride: 1,
        pad: 1,
    };
    let main = Sequential::new(vec![
        Box::new(Conv2d::new(&format!("{name}.c1"), g1, out_c, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn1"), out_c)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new(&format!("{name}.c2"), g2, out_c, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn2"), out_c)),
    ]);
    let shortcut = if stride != 1 || in_c != out_c {
        let gp = Conv2dGeom {
            in_c,
            in_h: hw,
            in_w: hw,
            k: 1,
            stride,
            pad: 0,
        };
        Some(Sequential::new(vec![
            Box::new(Conv2d::new(&format!("{name}.proj"), gp, out_c, LayerPos::Middle, false, rng)),
            Box::new(BatchNorm::new_2d(&format!("{name}.bnp"), out_c)),
        ]))
    } else {
        None
    };
    (Residual::new(main, shortcut), out_hw)
}

/// A bottleneck (1×1 reduce, 3×3, 1×1 expand) residual block with
/// expansion factor `exp`; returns the block and output spatial size.
#[allow(clippy::too_many_arguments)]
pub(crate) fn bottleneck_block(
    name: &str,
    in_c: usize,
    hw: usize,
    width: usize,
    exp: usize,
    stride: usize,
    rng: &mut Xoshiro256,
) -> (Residual, usize, usize) {
    let out_c = width * exp;
    let out_hw = (hw + 2 - 3) / stride + 1;
    let g1 = Conv2dGeom { in_c, in_h: hw, in_w: hw, k: 1, stride: 1, pad: 0 };
    let g2 = Conv2dGeom { in_c: width, in_h: hw, in_w: hw, k: 3, stride, pad: 1 };
    let g3 = Conv2dGeom { in_c: width, in_h: out_hw, in_w: out_hw, k: 1, stride: 1, pad: 0 };
    let main = Sequential::new(vec![
        Box::new(Conv2d::new(&format!("{name}.c1"), g1, width, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn1"), width)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new(&format!("{name}.c2"), g2, width, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn2"), width)),
        Box::new(Relu::new()),
        Box::new(Conv2d::new(&format!("{name}.c3"), g3, out_c, LayerPos::Middle, false, rng)),
        Box::new(BatchNorm::new_2d(&format!("{name}.bn3"), out_c)),
    ]);
    let shortcut = if stride != 1 || in_c != out_c {
        let gp = Conv2dGeom { in_c, in_h: hw, in_w: hw, k: 1, stride, pad: 0 };
        Some(Sequential::new(vec![
            Box::new(Conv2d::new(&format!("{name}.proj"), gp, out_c, LayerPos::Middle, false, rng)),
            Box::new(BatchNorm::new_2d(&format!("{name}.bnp"), out_c)),
        ]))
    } else {
        None
    };
    (Residual::new(main, shortcut), out_c, out_hw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{ModelSpec, PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn all_models_backward_under_paper_policy() {
        let policy = PrecisionPolicy::fp8_paper();
        let ctx = QuantCtx::new(&policy, 1, true);
        for spec in [ModelSpec::cifar_cnn(), ModelSpec::bn50_dnn()] {
            let mut m = spec.build(7);
            let x = Tensor::zeros(&spec.input().shape(2));
            let y = m.forward(x, &ctx);
            let dy = Tensor::full(&y.shape, 0.01);
            let dx = m.backward(dy, &ctx);
            assert_eq!(dx.shape, spec.input().shape(2), "{}", spec.id());
        }
    }

    #[test]
    fn model_state_dict_round_trips_through_residual_blocks() {
        use crate::state::{StateDict, StateMap};
        // CifarResnet exercises the full recursion: Sequential → Residual
        // (main + projection shortcut) → Conv/BN, including BN running
        // stats behind two levels of containers.
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let spec = ModelSpec::cifar_resnet();
        let mut m = spec.build(3);
        let x = Tensor::from_vec(
            &[2, 3, 32, 32],
            (0..2 * 3 * 32 * 32).map(|i| (i % 7) as f32 * 0.1).collect(),
        );
        m.forward(x, &ctx); // move BN running stats off their init values
        let mut map = StateMap::new();
        m.save_state("model", &mut map);
        let n_params = {
            let mut n = 0;
            m.visit_params(&mut |_| n += 1);
            n
        };
        assert!(map.len() > n_params, "extra state (BN stats) must be saved");
        let mut fresh = spec.build(99);
        fresh.load_state("model", &map).unwrap();
        let mut map2 = StateMap::new();
        fresh.save_state("model", &mut map2);
        assert_eq!(map, map2, "restored model must serialize bit-identically");
        // Strictness: a truncated map is rejected.
        let empty = StateMap::new();
        assert!(spec.build(0).load_state("model", &empty).is_err());
    }

    #[test]
    fn model_size_ordering_matches_table1() {
        // Table 1's model sizes are ordered CIFAR-CNN < CIFAR-ResNet <
        // ResNet18 < ResNet50 < AlexNet (FC-heavy); scaled versions must
        // preserve CNN < ResNet orderings at least.
        let n = |id: &str| ModelSpec::preset(id).unwrap().build(0).num_params();
        assert!(n("cifar_cnn") < n("cifar_resnet"));
        assert!(n("cifar_resnet") < n("resnet18"));
        assert!(n("resnet18") < n("resnet50"));
    }

    #[test]
    fn reference_builders_cover_every_preset() {
        for id in ModelSpec::PRESET_IDS {
            let mut m = reference_build(id, 3).unwrap_or_else(|| panic!("{id}"));
            assert!(m.num_params() > 1000, "{id}");
        }
        assert!(reference_build("nope", 0).is_none());
    }

    #[test]
    fn basic_block_shapes() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (mut b, out_hw) = basic_block("t", 8, 16, 16, 2, &mut rng);
        assert_eq!(out_hw, 8);
        let y = b.forward(Tensor::zeros(&[1, 8, 16, 16]), &ctx);
        assert_eq!(y.shape, vec![1, 16, 8, 8]);
        let dx = b.backward(Tensor::zeros(&[1, 16, 8, 8]), &ctx);
        assert_eq!(dx.shape, vec![1, 8, 16, 16]);
    }

    #[test]
    fn bottleneck_block_shapes() {
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let (mut b, out_c, out_hw) = bottleneck_block("t", 16, 8, 8, 4, 1, &mut rng);
        assert_eq!((out_c, out_hw), (32, 8));
        let y = b.forward(Tensor::zeros(&[1, 16, 8, 8]), &ctx);
        assert_eq!(y.shape, vec![1, 32, 8, 8]);
    }
}
