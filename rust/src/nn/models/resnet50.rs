//! ResNet50 (He et al. [9], Appendix A: 16 bottleneck blocks / 48 conv
//! layers). Scaled per DESIGN.md §7 to 32×32 / 100 classes: bottleneck
//! (1×1, 3×3, 1×1, expansion 4) blocks in 4 stages with the ImageNet
//! [2,2,2,2] depth reduction of the [3,4,6,3] pattern, widths
//! 16/32/64/128 (output channels up to 512) — 24 block convs + stem + 4
//! projections, preserving both the 1×1-heavy GEMM mix that makes ResNet50
//! the paper's chunking stress test (Fig. 5a) and the Table 1 model-size
//! ordering (ResNet50 > ResNet18, expansion-4 1×1 convs dominating).

use crate::nn::linear::Linear;
use crate::nn::models::{bottleneck_block, conv_bn_relu};
use crate::nn::pool::GlobalAvgPool;
use crate::nn::quant::LayerPos;
use crate::nn::{Layer, Sequential};
use crate::numerics::Xoshiro256;

pub const EXPANSION: usize = 4;

pub fn build(rng: &mut Xoshiro256) -> Sequential {
    let mut layers: Vec<Box<dyn Layer>> = Vec::new();
    layers.extend(conv_bn_relu("stem", 3, 32, 16, 3, 1, 1, LayerPos::First, rng));
    let mut c = 16;
    let mut hw = 32;
    for (s, &width) in [16usize, 32, 64, 128].iter().enumerate() {
        for b in 0..2 {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let (block, out_c, out_hw) =
                bottleneck_block(&format!("s{s}b{b}"), c, hw, width, EXPANSION, stride, rng);
            layers.push(Box::new(block));
            c = out_c;
            hw = out_hw;
        }
    }
    layers.push(Box::new(GlobalAvgPool::new()));
    layers.push(Box::new(Linear::new("fc", 128 * EXPANSION, 10, LayerPos::Last, rng)));
    Sequential::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn bottleneck_structure() {
        let mut m = build(&mut Xoshiro256::seed_from_u64(0));
        let mut convs = 0;
        m.visit_params(&mut |p| {
            if p.name.ends_with(".w") && !p.name.starts_with("fc") {
                convs += 1;
            }
        });
        // 1 stem + 8 blocks × 3 + projections (every stage's first block
        // projects since in_c != width·4): 4 projections + s0b0 projection
        // from 16→32 — count: blocks with stride 2 or channel change.
        assert_eq!(convs, 1 + 24 + 4);
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, false);
        let y = m.forward(Tensor::zeros(&[1, 3, 32, 32]), &ctx);
        assert_eq!(y.shape, vec![1, 10]);
    }
}
