//! CIFAR10-CNN (Krizhevsky & Hinton [14], Appendix A): 3 Conv layers with
//! 5×5 filters + ReLU, interleaved 2×2 max pooling, one FC layer and a
//! 10-way Softmax. This is the paper's smallest benchmark and the model the
//! E2E PJRT driver trains; it is used at full scale (no width reduction).

use crate::nn::act::Relu;
use crate::nn::conv::Conv2d;
use crate::nn::linear::Linear;
use crate::nn::pool::MaxPool2d;
use crate::nn::quant::LayerPos;
use crate::nn::{Flatten, Sequential};
use crate::numerics::Xoshiro256;
use crate::tensor::Conv2dGeom;

pub fn build(rng: &mut Xoshiro256) -> Sequential {
    let g = |in_c, hw| Conv2dGeom {
        in_c,
        in_h: hw,
        in_w: hw,
        k: 5,
        stride: 1,
        pad: 2,
    };
    Sequential::new(vec![
        // conv1: 3→16 @32, pool → 16
        Box::new(Conv2d::new("conv1", g(3, 32), 16, LayerPos::First, true, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2d::new(2, 2)),
        // conv2: 16→32 @16, pool → 8
        Box::new(Conv2d::new("conv2", g(16, 16), 32, LayerPos::Middle, true, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2d::new(2, 2)),
        // conv3: 32→32 @8, pool → 4
        Box::new(Conv2d::new("conv3", g(32, 8), 32, LayerPos::Middle, true, rng)),
        Box::new(Relu::new()),
        Box::new(MaxPool2d::new(2, 2)),
        Box::new(Flatten::new()),
        // fc: 512 → 10 (the Softmax-feeding last layer, FP16 under the
        // paper's scheme)
        Box::new(Linear::new("fc", 32 * 4 * 4, 10, LayerPos::Last, rng)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Layer, PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn shapes_and_param_count() {
        let mut m = build(&mut Xoshiro256::seed_from_u64(0));
        // conv1 3·25·16+16, conv2 16·25·32+32, conv3 32·25·32+32, fc 512·10+10
        let expect = (3 * 25 * 16 + 16) + (16 * 25 * 32 + 32) + (32 * 25 * 32 + 32) + (512 * 10 + 10);
        assert_eq!(m.num_params(), expect);
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let y = m.forward(Tensor::zeros(&[4, 3, 32, 32]), &ctx);
        assert_eq!(y.shape, vec![4, 10]);
    }
}
