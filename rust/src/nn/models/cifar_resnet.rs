//! CIFAR10-ResNet (Appendix A: 15 blocks / 31 conv layers, 3×3 filters,
//! BN, ReLU, final FC). Scaled per DESIGN.md §7: the canonical CIFAR
//! ResNet stage pattern (3 stages at 32/16/8 spatial) with 2 basic blocks
//! per stage and widths 16/32/64 — 13 conv layers, same block structure
//! and BN placement.

use crate::nn::linear::Linear;
use crate::nn::models::{basic_block, conv_bn_relu};
use crate::nn::pool::GlobalAvgPool;
use crate::nn::quant::LayerPos;
use crate::nn::{Layer, Sequential};
use crate::numerics::Xoshiro256;

pub fn build(rng: &mut Xoshiro256) -> Sequential {
    let mut layers: Vec<Box<dyn Layer>> = Vec::new();
    // Stem: 3→16 @32.
    layers.extend(conv_bn_relu("stem", 3, 32, 16, 3, 1, 1, LayerPos::First, rng));
    let mut c = 16;
    let mut hw = 32;
    for (s, &width) in [16usize, 32, 64].iter().enumerate() {
        for b in 0..2 {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            let (block, out_hw) = basic_block(&format!("s{s}b{b}"), c, hw, width, stride, rng);
            layers.push(Box::new(block));
            c = width;
            hw = out_hw;
        }
    }
    layers.push(Box::new(GlobalAvgPool::new()));
    layers.push(Box::new(Linear::new("fc", 64, 10, LayerPos::Last, rng)));
    Sequential::new(layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{PrecisionPolicy, QuantCtx};
    use crate::tensor::Tensor;

    #[test]
    fn forward_backward_shapes() {
        let mut m = build(&mut Xoshiro256::seed_from_u64(0));
        let policy = PrecisionPolicy::fp32();
        let ctx = QuantCtx::new(&policy, 0, true);
        let y = m.forward(Tensor::zeros(&[2, 3, 32, 32]), &ctx);
        assert_eq!(y.shape, vec![2, 10]);
        let dx = m.backward(Tensor::zeros(&[2, 10]), &ctx);
        assert_eq!(dx.shape, vec![2, 3, 32, 32]);
    }
}
