//! Named sweep presets: the paper-table grids and the related-work format
//! studies expressed as thin [`SweepDef`]s, so `fp8train sweep table2`
//! replays a whole comparison as one resumable artifact instead of a
//! hand-driven loop. The `exp` harnesses (`experiments/table2.rs`,
//! `table3.rs`, `fig6.rs`) remain the paper-faithful single-table
//! printers; these presets are the grid-shaped, machine-readable versions
//! of the same studies (every CLI axis/budget flag still overrides).

use super::SweepDef;

/// Preset ids, stable for the CLI help text.
pub const IDS: [&str; 4] = ["formats_x_arch", "table2", "table3", "fig6_chunks"];

fn strs(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

/// Look up a named sweep preset.
pub fn get(name: &str) -> Option<SweepDef> {
    Some(match name {
        // The Graphcore-style study: candidate 8-bit operand formats ×
        // a small conv/res architecture grid. `e4m3`/`e3m4` run the
        // paper's scheme with the alternative operand format (see
        // `sweep::resolve_policy`), so the grid isolates the (ebits,
        // mbits) trade-off across model shapes.
        "formats_x_arch" => {
            let mut d = SweepDef::new("conv3x3({8,16})-res(1x{16,32})-gap-fc(10)");
            d.formats = strs(&["fp32", "fp8_paper", "e4m3", "e3m4"]);
            d
        }
        // Table 2: reduced-precision training schemes on AlexNet — the
        // baseline schemes are policy presets, so the whole comparison is
        // one format axis.
        "table2" => {
            let mut d = SweepDef::new("alexnet");
            d.formats = strs(&["fp32", "dorefa", "wage", "dfp16", "mpt_fp16", "fp8_paper"]);
            d
        }
        // Table 3's last-layer lever as a position axis: `auto` keeps the
        // paper's FP16 last layer; `middle` demotes it to the FP8 middle
        // scheme while the Softmax input stays FP16 (the "FP8 GEMMs, FP16
        // softmax-in" row). The fp32 column shows the axis is a no-op for
        // full-precision policies.
        "table3" => {
            let mut d = SweepDef::new("alexnet");
            d.formats = strs(&["fp32", "fp8_paper"]);
            d.pos = strs(&["auto", "middle"]);
            d
        }
        // Fig. 6's accumulation-chunk-length lever on the CIFAR10 CNN.
        "fig6_chunks" => {
            let mut d = SweepDef::new("cifar_cnn");
            d.chunks = vec![1, 8, 64, 512];
            d
        }
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::expand;

    #[test]
    fn every_preset_expands() {
        for id in IDS {
            let def = get(id).unwrap_or_else(|| panic!("preset {id}"));
            let cells = expand(&def).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert!(!cells.is_empty(), "{id}");
            // Deterministic ids, no aliasing.
            let mut ids: Vec<String> = cells.iter().map(|c| c.id()).collect();
            let n = ids.len();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), n, "{id} grid has aliased cell ids");
        }
        assert!(get("nope").is_none());
    }

    #[test]
    fn preset_grid_shapes() {
        assert_eq!(expand(&get("formats_x_arch").unwrap()).unwrap().len(), 4 * 4);
        assert_eq!(expand(&get("table2").unwrap()).unwrap().len(), 6);
        assert_eq!(expand(&get("table3").unwrap()).unwrap().len(), 4);
        assert_eq!(expand(&get("fig6_chunks").unwrap()).unwrap().len(), 4);
    }
}
