//! `fp8train sweep` — format × architecture grid runs.
//!
//! The paper's headline claim is accuracy "on a spectrum of Deep Learning
//! models and datasets", and the follow-up studies (Graphcore's *8-bit
//! Numerical Formats for DNNs*, Mellempudi et al.) show the interesting
//! science lives in the **format × architecture grid**, not in single
//! runs. This module is the scenario-diversity harness for that grid:
//!
//! - a [`SweepDef`] crosses a **model-template axis** (a `ModelSpec` DSL
//!   string with `{a,b,c}` placeholders — widths, depths, even
//!   `@{middle,last}` precision positions; see
//!   [`ModelSpec::expand_template`]) with **format** (policy presets *or*
//!   bare float formats like `e4m3`), **round-mode**, **precision-position**
//!   (`auto|first|middle|last`, applied to the last GEMM item), **optimizer**
//!   and **chunk-size** axes;
//! - [`expand`] turns it into a deterministic, ordered list of [`Cell`]s —
//!   the leftmost/model axis varies slowest, and every cell has a
//!   canonical id string (the resume key);
//! - [`run`] drives each cell through the existing trainer
//!   (`train::train`, the same committed-run budget as
//!   `experiments::run_training`) and appends one record per cell to a
//!   single machine-readable artifact, `SWEEP.json` (schema documented in
//!   `docs/sweep.md`), with final loss/accuracy, the loss-curve tail,
//!   wall time and the per-phase [`crate::perf`] timings.
//!
//! **Resumable**: cells already recorded as `done` in an existing artifact
//! are skipped (their records carry over verbatim via
//! [`crate::benchcmp::Json::dump`]); a cell interrupted mid-run resumes
//! from its own `.fp8ck` checkpoint under `<out>.cells/` — the same
//! bit-exact `{step}`-checkpoint machinery the trainer uses, so an
//! interrupted-and-resumed cell is element-wise identical to an
//! uninterrupted one (`rust/tests/resume_equivalence.rs`).
//!
//! **Budgeted**: `--max-cells` bounds how many cells one invocation runs
//! (the rest are deferred, not forgotten), `--steps` bounds each cell, and
//! `--timeout-per-cell` is a soft wall-clock budget checked at segment
//! boundaries (a timed-out cell is recorded as `timeout`, keeps its
//! checkpoint, and is re-attempted — resumed, not restarted — by the next
//! invocation).
//!
//! **Supervised** (`--workers N`, N > 1): cells run as child
//! `fp8train sweep-worker` processes under [`crate::supervisor`] —
//! heartbeat monitoring, hard (kill + resume) timeouts, bounded retry
//! with backoff, and terminal `failed` statuses, so one crashing or
//! hanging cell never sinks the study (`docs/robustness.md`). The
//! serial and supervised paths emit byte-identical records under
//! `--deterministic`.
//!
//! **Guarded**: every cell trains under the numerical divergence guard
//! ([`crate::train::GuardCfg`]) — a cell whose loss goes non-finite for
//! consecutive steps, or blows past 1000× its first eval-window loss,
//! ends early with terminal status `diverged` instead of burning its
//! step budget.
//!
//! `sweep diff A B` compares two artifacts per-cell on the zero-dependency
//! JSON reader in [`crate::benchcmp`].

pub mod presets;

use std::collections::BTreeMap;
use std::time::Instant;

use crate::benchcmp::{escape, Json};
use crate::coordinator::NativeEngine;
use crate::data::SyntheticDataset;
use crate::error::{Context, Result};
use crate::experiments;
use crate::faults::FaultSpec;
use crate::nn::{LayerPos, ModelSpec, PrecisionPolicy};
use crate::nn::linear::layer_hash;
use crate::numerics::{FloatFormat, RoundMode};
use crate::optim::standard_optimizer;
use crate::perf::PhaseSnapshot;
use crate::state::{StateDict, StateError, StateMap};
use crate::train::{train_with, GuardCfg, LrSchedule, TrainConfig, TrainProgress, TrainResult};
use crate::{bail, ensure};

/// Artifact schema version (`SWEEP.json` → `"schema"`). Schema 2 added
/// the per-record `diverged_at` (null | step count) and `error`
/// (null | message) fields; schema 3 added `numerics` (null | the
/// [`crate::telemetry`] summary: first non-finite step and the top
/// saturating/underflowing (layer, role) entries), which makes a
/// `diverged` record self-explaining.
pub const SCHEMA: u64 = 3;

/// A sweep description: one template axis crossed with five value axes
/// plus the shared per-cell training budget. Every field participates in
/// the cell ids, so editing any of them re-keys the grid.
#[derive(Clone, Debug)]
pub struct SweepDef {
    /// Model template: a preset name or DSL string, with optional `{a,b,c}`
    /// placeholder axes.
    pub template: String,
    /// Format axis: policy presets (`fp32`, `fp8_paper`, `dorefa`, …) or
    /// bare float formats (`e4m3`, `1-5-2`, `bf16`, …) which run the
    /// paper's scheme with that GEMM operand format.
    pub formats: Vec<String>,
    /// Round-mode axis: `default` (the policy's own) or a
    /// [`RoundMode`] id applied to every non-FP32 GEMM.
    pub rounds: Vec<String>,
    /// Precision-position axis: `auto` (spec defaults) or
    /// `first|middle|last` applied to the last GEMM item.
    pub pos: Vec<String>,
    /// Optimizer axis: `sgd` | `adam`.
    pub opts: Vec<String>,
    /// Chunk-size axis: `0` keeps the policy's chunk, anything else
    /// overrides it (Fig. 6's accumulation-length lever).
    pub chunks: Vec<usize>,
    /// Training steps per cell.
    pub steps: usize,
    pub batch: usize,
    pub seed: u64,
}

impl SweepDef {
    /// A single-cell-per-model description: paper policy, default round
    /// mode / positions / chunking, SGD — each axis then widens from the
    /// CLI or a preset.
    pub fn new(template: &str) -> Self {
        Self {
            template: template.to_string(),
            formats: vec!["fp8_paper".into()],
            rounds: vec!["default".into()],
            pos: vec!["auto".into()],
            opts: vec!["sgd".into()],
            chunks: vec![0],
            steps: 300,
            batch: 32,
            seed: 42,
        }
    }
}

/// One concrete grid cell (resolved model id × one value per axis × the
/// shared budget).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Cell {
    /// Resolved model identity ([`ModelSpec::id`]): preset id or canonical
    /// DSL.
    pub model: String,
    pub fmt: String,
    pub round: String,
    pub pos: String,
    pub opt: String,
    pub chunk: usize,
    pub steps: usize,
    pub batch: usize,
    pub seed: u64,
}

impl Cell {
    /// The canonical cell id — the resume key. Built from the resolved
    /// model id and every axis + budget knob, so identical descriptions
    /// produce identical ids and *any* change re-runs the cell rather than
    /// silently reusing stale results.
    pub fn id(&self) -> String {
        format!(
            "{}|fmt={}|round={}|pos={}|opt={}|chunk={}|steps={}|batch={}|seed={}",
            self.model,
            self.fmt,
            self.round,
            self.pos,
            self.opt,
            self.chunk,
            self.steps,
            self.batch,
            self.seed
        )
    }
}

/// Runtime knobs of one `sweep` invocation (everything that does *not*
/// re-key the grid).
#[derive(Clone, Debug)]
pub struct RunOpts {
    /// Artifact path (`SWEEP.json`).
    pub out: String,
    /// Directory for in-cell durability checkpoints.
    pub cells_dir: String,
    /// Run at most this many cells this invocation (0 = unlimited); the
    /// rest are deferred to the next invocation, which skips completed
    /// cells.
    pub max_cells: usize,
    /// Soft per-cell wall-clock budget in seconds (0 = none), checked at
    /// segment boundaries.
    pub timeout_per_cell: f64,
    /// Loss-curve points kept per cell record.
    pub tail: usize,
    pub verbose: bool,
    /// Worker-process parallelism: 0 or 1 runs cells in-process (serial);
    /// N > 1 dispatches cells to N child `fp8train sweep-worker`
    /// processes under the supervisor ([`crate::supervisor`]), which also
    /// turns `timeout_per_cell` into a *hard* (kill + resume) timeout.
    pub workers: usize,
    /// Supervisor: attempts **without progress** (the cell's checkpoint
    /// did not advance across the attempt) tolerated per cell before it is
    /// recorded terminally as `failed` (crash) or `timeout` (stall/hard
    /// timeout).
    pub retries: usize,
    /// Supervisor: base respawn backoff; attempt n without progress waits
    /// `backoff_ms × 2^(n−1)` before the next spawn.
    pub backoff_ms: u64,
    /// Supervisor: a worker whose heartbeat-file *content* has not changed
    /// for this long is considered stuck and killed (0 disables).
    pub heartbeat_secs: f64,
    /// Zero the non-reproducible record fields (`wall_ms`, `phases`) so
    /// two runs of the same grid — serial or supervised, interrupted or
    /// not — emit byte-identical artifacts (the fault-tolerance CI check).
    pub deterministic: bool,
    /// Supervisor: worker binary to spawn (defaults to the current
    /// executable; a test hook).
    pub worker_exe: Option<String>,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            out: "SWEEP.json".into(),
            cells_dir: "SWEEP.json.cells".into(),
            max_cells: 0,
            timeout_per_cell: 0.0,
            tail: 5,
            verbose: false,
            workers: 0,
            retries: 3,
            backoff_ms: 250,
            heartbeat_secs: 30.0,
            deterministic: false,
            worker_exe: None,
        }
    }
}

/// Resolve a format-axis token: an inline JSON policy first (tokens
/// starting with `{` route to [`PrecisionPolicy::from_json`] — the
/// `--policy-json` escape hatch), then a [`PrecisionPolicy`] preset name
/// (`fp32`, `fp8_paper`, the Table 2 baselines, …), else a bare
/// [`FloatFormat`] spelling (`e4m3`, `1-5-2`, `bf16`, …) which runs the
/// paper's scheme — FP16 chunked accumulation, FP16-SR updates, FP16
/// first/last layers — with that GEMM operand format. The latter is the
/// Graphcore-style format axis.
pub fn resolve_policy(token: &str) -> Result<PrecisionPolicy> {
    if token.trim_start().starts_with('{') {
        return match PrecisionPolicy::from_json(token) {
            Ok(p) => Ok(p),
            Err(e) => bail!("{e}"),
        };
    }
    if let Some(p) = PrecisionPolicy::parse(token) {
        return Ok(p);
    }
    if let Some(fmt) = FloatFormat::parse(token) {
        let mut p = PrecisionPolicy::fp8_paper();
        for g in p.gemm.iter_mut() {
            g.fmt_mult = fmt;
        }
        return Ok(p.renamed(&format!("paper_{}", fmt.community_name())));
    }
    bail!(
        "unknown format-axis value {token:?} (policy presets: {}, …; or a float format: e4m3, 1-5-2, bf16, …)",
        PrecisionPolicy::PRESETS.join(", ")
    )
}

/// Parse a `--policy-json` file — one policy object or an array of them —
/// into format-axis tokens. Each token is the object's compact
/// [`Json::dump`] (key-sorted, so formatting-only edits don't re-key),
/// and it enters [`Cell::id`] verbatim: editing a policy's *content*
/// re-keys and re-runs exactly its cells. Every object is validated via
/// [`PrecisionPolicy::from_json`] up front so a bad file fails before the
/// grid expands.
pub fn policy_json_tokens(text: &str) -> Result<Vec<String>> {
    let v = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => bail!("policy json: {e}"),
    };
    let objs = match v {
        Json::Arr(a) => a,
        o @ Json::Obj(_) => vec![o],
        _ => bail!("policy json: top level must be an object or an array of objects"),
    };
    ensure!(!objs.is_empty(), "policy json: empty array");
    let mut toks = Vec::with_capacity(objs.len());
    for o in objs {
        let tok = o.dump();
        if let Err(e) = PrecisionPolicy::from_json(&tok) {
            bail!("{e}");
        }
        toks.push(tok);
    }
    Ok(toks)
}

fn parse_round_axis(token: &str) -> Result<Option<RoundMode>> {
    if token == "default" {
        return Ok(None);
    }
    match RoundMode::parse(token) {
        Some(m) => Ok(Some(m)),
        None => bail!(
            "unknown round-axis value {token:?} (default|nearest|nearest_away|truncate|stochastic)"
        ),
    }
}

fn parse_pos_axis(token: &str) -> Result<Option<LayerPos>> {
    Ok(match token {
        "auto" => None,
        "first" => Some(LayerPos::First),
        "middle" => Some(LayerPos::Middle),
        "last" => Some(LayerPos::Last),
        other => bail!("unknown pos-axis value {other:?} (auto|first|middle|last)"),
    })
}

fn ensure_unique(axis: &str, values: &[String]) -> Result<()> {
    for (i, a) in values.iter().enumerate() {
        ensure!(
            !values[i + 1..].contains(a),
            "duplicate {axis}-axis value {a:?} would alias cell ids"
        );
    }
    Ok(())
}

/// Expand a description into the ordered cell list. Deterministic — the
/// contract the resume key depends on: model (template order, leftmost
/// placeholder slowest) ≫ format ≫ round ≫ pos ≫ opt ≫ chunk. Every axis
/// value is validated here, once, so `run` cannot trip over a typo five
/// cells in.
pub fn expand(def: &SweepDef) -> Result<Vec<Cell>> {
    ensure!(def.steps > 0, "sweep needs --steps ≥ 1");
    ensure!(def.batch > 0, "sweep needs --batch ≥ 1");
    // The artifact stores numbers as f64 (the zero-dep JSON reader), so a
    // seed above 2^53 would canonicalize to a *different* integer than
    // the one in the cell id. Refuse rather than silently drift.
    ensure!(
        def.seed <= (1u64 << 53),
        "sweep seeds must fit in 53 bits (JSON numbers are f64), got {}",
        def.seed
    );
    for (axis, values) in [
        ("format", &def.formats),
        ("round", &def.rounds),
        ("pos", &def.pos),
        ("opt", &def.opts),
    ] {
        ensure!(!values.is_empty(), "sweep needs at least one {axis}-axis value");
        ensure_unique(axis, values)?;
    }
    // Raw-spelling dedup above catches literal repeats; alias spellings
    // ("e4m3" vs "1-4-3", "stochastic" vs "sr") would still train
    // byte-identical cells under distinct ids, so dedup the value axes on
    // their *resolved* identity too (the model axis does the same via
    // spec.id()).
    ensure_unique(
        "format (resolved)",
        &def.formats
            .iter()
            .map(|f| resolve_policy(f).map(|p| p.name))
            .collect::<Result<Vec<_>>>()?,
    )?;
    ensure_unique(
        "round (resolved)",
        &def.rounds
            .iter()
            .map(|r| Ok(parse_round_axis(r)?.map_or("default", RoundMode::id).to_string()))
            .collect::<Result<Vec<_>>>()?,
    )?;
    ensure_unique(
        "pos (resolved)",
        &def.pos
            .iter()
            .map(|p| Ok(format!("{:?}", parse_pos_axis(p)?)))
            .collect::<Result<Vec<_>>>()?,
    )?;
    ensure!(!def.chunks.is_empty(), "sweep needs at least one chunk-axis value");
    for (i, c) in def.chunks.iter().enumerate() {
        ensure!(
            !def.chunks[i + 1..].contains(c),
            "duplicate chunk-axis value {c} would alias cell ids"
        );
    }
    let expansions = ModelSpec::expand_template(&def.template)
        .with_context(|| format!("expand template {:?}", def.template))?;
    let mut models = Vec::with_capacity(expansions.len());
    for m in &expansions {
        let spec =
            ModelSpec::resolve(m).with_context(|| format!("template expansion {m:?}"))?;
        // Validate every pos override against every model now (a spec with
        // no GEMM item, say, must fail at expansion time).
        for p in &def.pos {
            if let Some(pos) = parse_pos_axis(p)? {
                spec.with_pos_override(pos).with_context(|| {
                    format!("pos-axis value {p:?} on template expansion {m:?}")
                })?;
            }
        }
        let id = spec.id();
        ensure!(
            !models.contains(&id),
            "template expansions {m:?} and an earlier one both resolve to model {id:?}"
        );
        models.push(id);
    }
    // (formats and rounds were validated by the resolved-dedup pass above.)
    for o in &def.opts {
        ensure!(
            standard_optimizer(o, 0).is_some(),
            "unknown opt-axis value {o:?} (sgd|adam)"
        );
    }
    let mut cells = Vec::new();
    for m in &models {
        for f in &def.formats {
            for r in &def.rounds {
                for p in &def.pos {
                    for o in &def.opts {
                        for &c in &def.chunks {
                            cells.push(Cell {
                                model: m.clone(),
                                fmt: f.clone(),
                                round: r.clone(),
                                pos: p.clone(),
                                opt: o.clone(),
                                chunk: c,
                                steps: def.steps,
                                batch: def.batch,
                                seed: def.seed,
                            });
                        }
                    }
                }
            }
        }
    }
    Ok(cells)
}

/// `sweep … --list`: print the expanded grid (cell ids in run order)
/// without training anything — the determinism contract made visible.
pub fn list(def: &SweepDef) -> Result<()> {
    let cells = expand(def)?;
    println!("{} cells:", cells.len());
    for (i, c) in cells.iter().enumerate() {
        println!("[{i:>4}] {}", c.id());
    }
    Ok(())
}

/// `null` for non-finite values (a diverged cell's loss is NaN; the
/// artifact must stay valid JSON).
fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

/// What the table renderer needs to say about one cell.
pub(crate) struct CellSummary {
    pub(crate) status: String,
    pub(crate) final_err: Option<f64>,
    pub(crate) final_loss: Option<f64>,
    pub(crate) wall_ms: Option<f64>,
    /// Durability checkpoint to delete once the caller has persisted the
    /// record (set for the terminal `done`/`diverged` statuses).
    pub(crate) ck_to_remove: Option<String>,
}

/// The durability-checkpoint path of a cell — shared by the serial
/// runner, the worker and the supervisor, which must all agree on it.
pub(crate) fn cell_ck_path(cells_dir: &str, cell: &Cell) -> String {
    format!("{}/cell_{:016x}.fp8ck", cells_dir, layer_hash(&cell.id()))
}

/// Serialize one cell record (`docs/sweep.md` documents the schema).
/// `diverged_at` is the divergence-guard step for `diverged` records;
/// `error` is the failure description for supervisor-emitted `failed`
/// records; `numerics` is the cell's telemetry summary
/// ([`crate::telemetry::numerics_summary_json`], already-serialized
/// JSON). All three serialize as `null` when absent.
#[allow(clippy::too_many_arguments)]
pub(crate) fn cell_json(
    cell: &Cell,
    status: &str,
    steps_done: usize,
    wall_ms: f64,
    r: Option<&TrainResult>,
    phases: &PhaseSnapshot,
    stepped: u64,
    tail: usize,
    diverged_at: Option<usize>,
    error: Option<&str>,
    numerics: Option<&str>,
) -> String {
    let (final_train_loss, final_test_loss, final_test_err, best_test_err) = match r {
        Some(r) => (
            jnum(r.final_train_loss),
            jnum(r.curve.last().map(|p| p.test_loss).unwrap_or(f64::NAN)),
            jnum(r.final_test_err),
            jnum(r.best_test_err()),
        ),
        None => ("null".into(), "null".into(), "null".into(), "null".into()),
    };
    let curve_tail = match r {
        Some(r) => {
            let skip = r.curve.len().saturating_sub(tail);
            let pts: Vec<String> = r.curve[skip..]
                .iter()
                .map(|p| {
                    format!(
                        "{{\"step\":{},\"train_loss\":{},\"test_loss\":{},\"test_err\":{}}}",
                        p.step,
                        jnum(p.train_loss),
                        jnum(p.test_loss),
                        jnum(p.test_err)
                    )
                })
                .collect();
            format!("[{}]", pts.join(","))
        }
        None => "[]".into(),
    };
    let diverged_at = diverged_at.map_or_else(|| "null".to_string(), |d| d.to_string());
    let error = error.map_or_else(|| "null".to_string(), |e| format!("\"{}\"", escape(e)));
    let numerics = numerics.unwrap_or("null");
    format!(
        "{{\"id\":\"{}\",\"model\":\"{}\",\"fmt\":\"{}\",\"round\":\"{}\",\"pos\":\"{}\",\
         \"opt\":\"{}\",\"chunk\":{},\"steps\":{},\"batch\":{},\"seed\":{},\
         \"status\":\"{}\",\"steps_done\":{},\"wall_ms\":{},\
         \"final_train_loss\":{},\"final_test_loss\":{},\"final_test_err\":{},\
         \"best_test_err\":{},\"diverged_at\":{},\"error\":{},\"numerics\":{},\
         \"curve_tail\":{},\"phases\":{}}}",
        escape(&cell.id()),
        escape(&cell.model),
        escape(&cell.fmt),
        escape(&cell.round),
        escape(&cell.pos),
        escape(&cell.opt),
        cell.chunk,
        cell.steps,
        cell.batch,
        cell.seed,
        status,
        steps_done,
        jnum(wall_ms),
        final_train_loss,
        final_test_loss,
        final_test_err,
        best_test_err,
        diverged_at,
        error,
        numerics,
        curve_tail,
        phases.to_json(stepped)
    )
}

/// Atomically (write + rename) emit the artifact from the records
/// collected so far, in grid order.
pub(crate) fn write_artifact(path: &str, def: &SweepDef, records: &[String]) -> Result<()> {
    let strs = |v: &[String]| {
        v.iter()
            .map(|s| format!("\"{}\"", escape(s)))
            .collect::<Vec<_>>()
            .join(",")
    };
    let chunks = def
        .chunks
        .iter()
        .map(|c| c.to_string())
        .collect::<Vec<_>>()
        .join(",");
    let doc = format!(
        "{{\"schema\":{},\"description\":{{\"template\":\"{}\",\"formats\":[{}],\
         \"rounds\":[{}],\"pos\":[{}],\"opts\":[{}],\"chunks\":[{}],\"steps\":{},\
         \"batch\":{},\"seed\":{}}},\"cells\":[{}]}}\n",
        SCHEMA,
        escape(&def.template),
        strs(&def.formats),
        strs(&def.rounds),
        strs(&def.pos),
        strs(&def.opts),
        chunks,
        def.steps,
        def.batch,
        def.seed,
        records.join(",")
    );
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, &doc).with_context(|| format!("write {tmp}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp} → {path}"))?;
    Ok(())
}

/// Read an existing artifact's cell records (id → record). A missing file
/// is an empty map; an unreadable or wrong-schema file is an error (never
/// silently overwrite something that wasn't ours).
pub(crate) fn load_artifact(path: &str) -> Result<BTreeMap<String, Json>> {
    let mut out = BTreeMap::new();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        // Anything else (permissions, I/O) must not read as "no artifact"
        // — that would re-train the grid and clobber the real file.
        Err(e) => bail!("read existing artifact {path}: {e}"),
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => bail!(
            "existing artifact {path} is not valid JSON ({e}); move it aside or delete it"
        ),
    };
    let schema = doc.at("schema").and_then(Json::num).unwrap_or(0.0);
    ensure!(
        schema == SCHEMA as f64,
        "artifact {path} has schema {schema}, this build reads schema {}",
        SCHEMA
    );
    if let Some(Json::Arr(cells)) = doc.at("cells") {
        for cell in cells {
            if let Some(id) = cell.at("id").and_then(Json::str_val) {
                out.insert(id.to_string(), cell.clone());
            }
        }
    }
    Ok(out)
}

/// Train one cell, in eval-aligned segments with checkpoint durability.
///
/// Segments: `eval_every = max(1, steps/5)` (the `run_training` cadence)
/// doubles as the segment length, and every segment end writes the cell's
/// `.fp8ck`. Because eval points align with segment boundaries, the
/// recorded curve — and, by the bit-exact resume contract, the weights —
/// are identical however often the cell was interrupted.
///
/// Every cell trains under the divergence guard (`GuardCfg`: patience 3,
/// 1000× loss-window factor) — a diverged cell breaks out of the segment
/// loop with terminal status `diverged` and no further checkpoints. A
/// `FP8TRAIN_FAULT` spec matching this cell (and the current attempt) is
/// threaded into the trainer for deterministic fault injection.
///
/// `prior_wall_ms` is the wall time already recorded for this cell by a
/// previous (interrupted/timed-out) invocation; the emitted `wall_ms`
/// accumulates it, so the artifact reports the cell's total wall time
/// across resumes. `heartbeat` is the liveness file a supervised worker
/// touches every step; `soft_timeout` gates the `--timeout-per-cell`
/// segment-boundary check (the supervisor enforces timeouts by kill
/// instead, so its workers run with `soft_timeout = false`).
pub(crate) fn run_cell(
    cell: &Cell,
    opts: &RunOpts,
    prior_wall_ms: f64,
    heartbeat: Option<&str>,
    soft_timeout: bool,
) -> Result<(String, CellSummary)> {
    let id = cell.id();
    let spec = ModelSpec::resolve(&cell.model)?;
    // LR comes from the *un-overridden* spec: a pos override drops the
    // preset tag, and the pos axis must not smuggle in a different
    // base_lr (cells across the axis share every other hyper-parameter).
    let base_lr = experiments::base_lr(&spec);
    let spec = match parse_pos_axis(&cell.pos)? {
        Some(pos) => spec.with_pos_override(pos)?,
        None => spec,
    };
    let mut policy = resolve_policy(&cell.fmt)?;
    if let Some(mode) = parse_round_axis(&cell.round)? {
        policy = policy.with_round(mode);
    }
    if cell.chunk > 0 {
        policy = policy.with_chunk(cell.chunk);
    }
    // Engine construction is repeatable: a corrupt checkpoint may have
    // partially mutated the engine before its load failed, so
    // restart-from-scratch rebuilds rather than reuses.
    let make_engine = |policy: &PrecisionPolicy| -> Result<NativeEngine> {
        let opt = standard_optimizer(&cell.opt, cell.seed)
            .with_context(|| format!("unknown opt-axis value {:?} (sgd|adam)", cell.opt))?;
        Ok(NativeEngine::with_optimizer(&spec, policy.clone(), opt, cell.seed))
    };
    // The committed-run budget of experiments::run_training: 1024 train /
    // 128 test examples — cells stay comparable with the table harnesses.
    let ds = SyntheticDataset::for_model(&spec, cell.seed).with_sizes(1024, 128);
    let mut engine = make_engine(&policy)?;

    std::fs::create_dir_all(&opts.cells_dir)
        .with_context(|| format!("create cell-checkpoint dir {}", opts.cells_dir))?;
    let ck = cell_ck_path(&opts.cells_dir, cell);
    // In-cell durability: a half-finished cell resumes from its checkpoint.
    // The progress struct is caller-held (satellite of `train_with`) so one
    // restore covers every segment this invocation runs.
    let mut progress = TrainProgress::default();
    // Telemetry counters start from zero for a fresh cell; a successful
    // checkpoint restore below *replaces* them (the blob rides in the
    // checkpoint), so a resumed cell's numerics summary is identical to
    // an uninterrupted one's — the deterministic-artifact contract.
    crate::telemetry::reset();
    if std::path::Path::new(&ck).exists() {
        let restored = (|| -> std::result::Result<(), StateError> {
            let map = StateMap::load_file(&ck)?;
            engine.load_state(&map)?;
            progress.load_state("train", &map)?;
            if progress.next_step > cell.steps {
                return Err(StateError::Incompatible(format!(
                    "checkpoint is at step {}, beyond the cell's {}-step budget",
                    progress.next_step, cell.steps
                )));
            }
            Ok(())
        })();
        if let Err(e) = restored {
            // Truncated/corrupt/mismatched leftovers (or a hash collision
            // with some other file) restart the cell rather than poisoning
            // it — the supervisor relies on this after killing a worker
            // mid-checkpoint-write.
            crate::log_warn!("cell checkpoint {ck} is unusable ({e}); restarting cell from scratch");
            std::fs::remove_file(&ck).ok();
            engine = make_engine(&policy)?;
            progress = TrainProgress::default();
            // The failed restore may have gotten far enough to replace the
            // telemetry state from the bad checkpoint — back to zero.
            crate::telemetry::reset();
        }
    }
    let seg = (cell.steps / 5).max(1);
    let mut cfg = TrainConfig::quick(cell.steps);
    cfg.batch_size = cell.batch;
    cfg.schedule = LrSchedule::step_decay(base_lr, cell.steps);
    cfg.eval_every = seg;
    cfg.verbose = opts.verbose;
    cfg.save_path = Some(ck.clone());
    cfg.save_every = 0; // one save per segment (at its final step)
    cfg.guard = GuardCfg {
        nan_patience: 3,
        diverge_factor: 1e3,
    };
    cfg.fault = FaultSpec::from_env()?.filter(|f| f.applies(&id));
    cfg.heartbeat = heartbeat.map(String::from);

    let start = Instant::now();
    let p0 = crate::perf::snapshot();
    let mut stepped = 0u64;
    let mut timed_out = false;
    let (diverged_at, result) = loop {
        let next = progress.next_step;
        let target = ((next + seg).min(cell.steps)).max(next);
        cfg.steps = target;
        let r = train_with(&mut engine, &ds, &cfg, &mut progress);
        stepped += (r.diverged_at.unwrap_or(target).saturating_sub(next)) as u64;
        // A diverged segment does not advance next_step — break on it
        // explicitly or the loop would re-run the same segment forever.
        if r.diverged_at.is_some() || progress.next_step >= cell.steps {
            break (r.diverged_at, r);
        }
        if soft_timeout
            && opts.timeout_per_cell > 0.0
            && start.elapsed().as_secs_f64() >= opts.timeout_per_cell
        {
            timed_out = true;
            break (None, r);
        }
    };
    // --deterministic zeroes every timing-derived field so two runs of the
    // same grid — serial vs supervised, interrupted vs not — emit
    // byte-identical records.
    let (wall_ms, phases, stepped) = if opts.deterministic {
        (0.0, PhaseSnapshot::default(), 0)
    } else {
        (
            prior_wall_ms + start.elapsed().as_secs_f64() * 1e3,
            crate::perf::snapshot().since(&p0),
            stepped,
        )
    };
    let status = if diverged_at.is_some() {
        "diverged"
    } else if timed_out {
        "timeout"
    } else {
        "done"
    };
    let steps_done = diverged_at.unwrap_or(progress.next_step);
    // The cumulative numerics summary — for a `diverged` cell this is the
    // explanation: the first non-finite step and which (layer, role)
    // pairs were saturating/underflowing. Counter state is deterministic
    // (persisted through checkpoints, no clocks), so it is emitted even
    // under --deterministic.
    let numerics = crate::telemetry::numerics_summary_json();
    let record = cell_json(
        cell,
        status,
        steps_done,
        wall_ms,
        Some(&result),
        &phases,
        stepped,
        opts.tail,
        diverged_at,
        None,
        Some(&numerics),
    );
    // Normalize through the parser (also a self-check): carried-over and
    // fresh records then share one canonical serialization, so a re-run
    // over a complete grid rewrites the artifact byte-identically.
    let record = match Json::parse(&record) {
        Ok(v) => v.dump(),
        Err(e) => bail!("internal: record for cell {id} is not valid JSON: {e}"),
    };
    let summary = CellSummary {
        status: status.to_string(),
        final_err: Some(result.final_test_err),
        final_loss: Some(result.final_train_loss),
        wall_ms: Some(wall_ms),
        // A terminal (done/diverged) record supersedes its checkpoint; a
        // timed-out cell keeps it so the next invocation resumes instead
        // of restarting.
        ck_to_remove: (!timed_out).then_some(ck),
    };
    Ok((record, summary))
}

/// Run the grid: skip cells already terminal (`done`/`diverged`) in the
/// artifact, resume interrupted/timed-out ones, honor the `--max-cells`
/// budget, rewrite the artifact after every completed cell, and render the
/// summary table. With `--workers N` (N > 1) the grid runs under
/// [`crate::supervisor::run_supervised`] instead — child processes,
/// heartbeats, kill-based timeouts and bounded retry.
pub fn run(def: &SweepDef, opts: &RunOpts) -> Result<()> {
    if opts.workers > 1 {
        return crate::supervisor::run_supervised(def, opts);
    }
    let cells = expand(def)?;
    let old = load_artifact(&opts.out)?;
    println!(
        "sweep: {} cells from template {:?} → {}",
        cells.len(),
        def.template,
        opts.out
    );
    // One record slot per grid cell, pre-seeded with the existing
    // artifact's record for that cell (any status). Every write emits the
    // whole slot list, so a mid-pass interrupt can never drop a record for
    // a cell this pass has not reached yet — previously-done cells later
    // in grid order (whose checkpoints are already gone) and timeout
    // records of deferred cells all survive.
    let mut slots: Vec<Option<String>> = cells
        .iter()
        .map(|c| old.get(&c.id()).map(Json::dump))
        .collect();
    let emit = |slots: &[Option<String>]| -> Result<()> {
        let records: Vec<String> = slots.iter().flatten().cloned().collect();
        write_artifact(&opts.out, def, &records)
    };
    let mut rows: Vec<(Cell, String, Option<f64>, Option<f64>, Option<f64>)> = Vec::new();
    let (mut ran, mut skipped, mut deferred, mut timeouts, mut diverged) =
        (0usize, 0usize, 0usize, 0usize, 0usize);
    for (idx, cell) in cells.iter().enumerate() {
        let id = cell.id();
        let prior_status = old
            .get(&id)
            .and_then(|rec| rec.at("status").and_then(Json::str_val));
        // `done` and `diverged` are both terminal: re-running a diverged
        // cell would deterministically diverge again. `timeout` and
        // `failed` (supervised runs) are re-attempted.
        if let Some(status @ ("done" | "diverged")) = prior_status {
            let rec = &old[&id];
            rows.push((
                cell.clone(),
                format!("{status} (skipped)"),
                rec.at("final_test_err").and_then(Json::num),
                rec.at("final_train_loss").and_then(Json::num),
                rec.at("wall_ms").and_then(Json::num),
            ));
            skipped += 1;
            continue;
        }
        if opts.max_cells > 0 && ran >= opts.max_cells {
            deferred += 1;
            rows.push((cell.clone(), "deferred".into(), None, None, None));
            continue;
        }
        if opts.verbose {
            crate::log_info!("sweep cell {id}");
        }
        let prior_wall = old
            .get(&id)
            .and_then(|r| r.at("wall_ms").and_then(Json::num))
            .unwrap_or(0.0);
        let (record, s) = run_cell(cell, opts, prior_wall, None, true)?;
        slots[idx] = Some(record);
        // Persist after every cell so an interrupt costs at most one cell
        // — and delete the in-cell checkpoint only once its record is
        // durable.
        emit(&slots)?;
        if let Some(ck) = &s.ck_to_remove {
            std::fs::remove_file(ck).ok();
        }
        if s.status == "timeout" {
            timeouts += 1;
        }
        if s.status == "diverged" {
            diverged += 1;
        }
        ran += 1;
        rows.push((cell.clone(), s.status, s.final_err, s.final_loss, s.wall_ms));
    }
    emit(&slots)?;
    render_table(&rows);
    // `failed` is a supervised-only terminal status (a worker crashing
    // repeatedly); the serial path can't produce it but reports the column
    // so the two paths' summaries line up.
    let failed = 0usize;
    println!(
        "sweep complete: {ran} run, {skipped} skipped (already complete in {}), \
         {deferred} deferred by --max-cells, {timeouts} timed out, \
         {diverged} diverged, {failed} failed",
        opts.out
    );
    Ok(())
}

/// The compact terminal table: one row per grid cell, in run order.
pub(crate) fn render_table(rows: &[(Cell, String, Option<f64>, Option<f64>, Option<f64>)]) {
    let num = |v: &Option<f64>| match v {
        Some(v) => format!("{v:.3}"),
        None => "-".into(),
    };
    println!(
        "{:<34} {:<12} {:<10} {:<6} {:<4} {:>5} {:<15} {:>8} {:>9} {:>10}",
        "model", "fmt", "round", "pos", "opt", "chunk", "status", "err_%", "loss", "wall_ms"
    );
    for (c, status, err, loss, wall) in rows {
        let mut model = c.model.clone();
        if model.len() > 34 {
            model.truncate(31);
            model.push_str("...");
        }
        println!(
            "{:<34} {:<12} {:<10} {:<6} {:<4} {:>5} {:<15} {:>8} {:>9} {:>10}",
            model,
            c.fmt,
            c.round,
            c.pos,
            c.opt,
            c.chunk,
            status,
            num(err),
            num(loss),
            num(wall)
        );
    }
}

/// `fp8train sweep diff A B` — per-cell comparison of two artifacts (the
/// CI smoke job diffs an artifact against itself to validate it).
pub fn diff(a_path: &str, b_path: &str) -> Result<()> {
    ensure!(
        std::path::Path::new(a_path).exists(),
        "no sweep artifact at {a_path}"
    );
    ensure!(
        std::path::Path::new(b_path).exists(),
        "no sweep artifact at {b_path}"
    );
    let a = load_artifact(a_path)?;
    let b = load_artifact(b_path)?;
    println!("== sweep diff: A = {a_path}, B = {b_path} ==");
    println!(
        "{:<64} {:>9} {:>9} {:>9}",
        "cell", "A err_%", "B err_%", "delta"
    );
    let (mut compared, mut only_a, mut only_b) = (0usize, 0usize, 0usize);
    for (id, ra) in &a {
        let Some(rb) = b.get(id) else {
            only_a += 1;
            continue;
        };
        compared += 1;
        let ea = ra.at("final_test_err").and_then(Json::num);
        let eb = rb.at("final_test_err").and_then(Json::num);
        let fmt1 = |v: Option<f64>| v.map_or_else(|| "-".to_string(), |x| format!("{x:.3}"));
        let delta = match (ea, eb) {
            (Some(x), Some(y)) => format!("{:+.3}", y - x),
            _ => "-".into(),
        };
        let mut short = id.clone();
        if short.len() > 64 {
            short.truncate(61);
            short.push_str("...");
        }
        println!("{:<64} {:>9} {:>9} {:>9}", short, fmt1(ea), fmt1(eb), delta);
    }
    for id in b.keys() {
        if !a.contains_key(id) {
            only_b += 1;
        }
    }
    println!(
        "{compared} shared cells, {only_a} only in A, {only_b} only in B"
    );
    Ok(())
}

/// `fp8train sweep render ARTIFACT [--csv] [--out PATH]` — turn a sweep
/// artifact into a report: the grid with final/best error per cell,
/// diverged cells annotated with the divergence step and the top
/// saturating layer from the record's schema-3 `numerics` summary.
/// Markdown by default, `--csv` for a flat table; `--out PATH` writes a
/// file instead of stdout.
pub fn render(path: &str, csv: bool, out: Option<&str>) -> Result<()> {
    ensure!(
        std::path::Path::new(path).exists(),
        "no sweep artifact at {path}"
    );
    let records = load_artifact(path)?;
    let report = render_report(path, &records, csv);
    match out {
        Some(p) => {
            std::fs::write(p, &report).with_context(|| format!("write {p}"))?;
            println!("wrote {p} ({} cells)", records.len());
        }
        None => print!("{report}"),
    }
    Ok(())
}

/// The divergence / failure annotation for one record: empty for healthy
/// cells, `diverged at step N; top saturating layer L (R% sat)` for
/// diverged ones (the layer from the record's `numerics` summary), the
/// stored error message for `failed`/`timeout`.
fn render_note(rec: &Json) -> String {
    match rec.at("status").and_then(Json::str_val) {
        Some("diverged") => {
            let at = rec
                .at("diverged_at")
                .and_then(Json::num)
                .map_or_else(|| "?".to_string(), |x| format!("{}", x as u64));
            match (
                rec.at("numerics.layers.0.name").and_then(Json::str_val),
                rec.at("numerics.layers.0.sat_rate").and_then(Json::num),
            ) {
                (Some(layer), Some(rate)) => format!(
                    "diverged at step {at}; top saturating layer {layer} ({:.2}% sat)",
                    rate * 100.0
                ),
                _ => format!("diverged at step {at}"),
            }
        }
        Some("failed" | "timeout") => {
            let mut e = rec
                .at("error")
                .and_then(Json::str_val)
                .unwrap_or("")
                .to_string();
            if e.len() > 80 {
                e.truncate(77);
                e.push_str("...");
            }
            e
        }
        _ => String::new(),
    }
}

/// The report body — a pure function of the loaded records (BTreeMap ⇒
/// cell-id order ⇒ byte-stable output, which the golden test pins).
pub(crate) fn render_report(path: &str, records: &BTreeMap<String, Json>, csv: bool) -> String {
    let s = |rec: &Json, key: &str| {
        rec.at(key)
            .and_then(Json::str_val)
            .unwrap_or("-")
            .to_string()
    };
    let fmt3 = |v: Option<f64>| v.map_or_else(|| "-".to_string(), |x| format!("{x:.3}"));
    let fmt0 = |v: Option<f64>| v.map_or_else(|| "-".to_string(), |x| format!("{x:.0}"));
    // Conditional CSV quoting: inline-JSON fmt tokens (`--policy-json`)
    // contain commas and quotes, and they also ride inside the cell id.
    // Plain tokens stay unquoted so historical CSV output is byte-stable.
    let csv_field = |v: &str| -> String {
        if v.contains(',') || v.contains('"') {
            format!("\"{}\"", v.replace('"', "\"\""))
        } else {
            v.to_string()
        }
    };
    if csv {
        let mut out = String::from(
            "id,model,fmt,round,pos,opt,chunk,status,steps_done,\
             final_test_err,best_test_err,wall_ms,note\n",
        );
        for (id, rec) in records {
            let note = render_note(rec).replace('"', "\"\"");
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},\"{note}\"\n",
                csv_field(id),
                s(rec, "model"),
                csv_field(&s(rec, "fmt")),
                s(rec, "round"),
                s(rec, "pos"),
                s(rec, "opt"),
                fmt0(rec.at("chunk").and_then(Json::num)),
                s(rec, "status"),
                fmt0(rec.at("steps_done").and_then(Json::num)),
                fmt3(rec.at("final_test_err").and_then(Json::num)),
                fmt3(rec.at("best_test_err").and_then(Json::num)),
                fmt0(rec.at("wall_ms").and_then(Json::num)),
            ));
        }
        return out;
    }
    let mut out = format!(
        "# Sweep report: {path}\n\n{} cells (artifact schema {SCHEMA}).\n\n\
         | model | fmt | round | pos | opt | chunk | status | final err % | best err % | wall ms | notes |\n\
         |---|---|---|---|---|---|---|---|---|---|---|\n",
        records.len()
    );
    let (mut done, mut diverged, mut failed, mut timeout) = (0usize, 0usize, 0usize, 0usize);
    for rec in records.values() {
        match rec.at("status").and_then(Json::str_val) {
            Some("done") => done += 1,
            Some("diverged") => diverged += 1,
            Some("failed") => failed += 1,
            Some("timeout") => timeout += 1,
            _ => {}
        }
        out.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
            s(rec, "model"),
            s(rec, "fmt"),
            s(rec, "round"),
            s(rec, "pos"),
            s(rec, "opt"),
            fmt0(rec.at("chunk").and_then(Json::num)),
            s(rec, "status"),
            fmt3(rec.at("final_test_err").and_then(Json::num)),
            fmt3(rec.at("best_test_err").and_then(Json::num)),
            fmt0(rec.at("wall_ms").and_then(Json::num)),
            render_note(rec).replace('|', "\\|"),
        ));
    }
    out.push_str(&format!(
        "\n**Summary:** {done} done, {diverged} diverged, {failed} failed, {timeout} timed out.\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_def() -> SweepDef {
        let mut def = SweepDef::new("mlp(6,{4,5},3)");
        def.formats = vec!["fp32".into(), "fp8_paper".into()];
        def.steps = 2;
        def.batch = 4;
        def.seed = 9;
        def
    }

    #[test]
    fn render_report_markdown_and_csv_are_golden() {
        let rec_done = Json::parse(
            r#"{"id":"a","model":"mnist_dnn","fmt":"fp8_paper","round":"default",
                "pos":"auto","opt":"sgd","chunk":64,"steps":100,"batch":32,"seed":7,
                "status":"done","steps_done":100,"wall_ms":1234,
                "final_train_loss":0.5,"final_test_loss":0.6,
                "final_test_err":2.375,"best_test_err":2.25,
                "diverged_at":null,"error":null,"numerics":null}"#,
        )
        .unwrap();
        let rec_div = Json::parse(
            r#"{"id":"b","model":"mnist_dnn","fmt":"e4m3","round":"default",
                "pos":"auto","opt":"sgd","chunk":64,"steps":100,"batch":32,"seed":7,
                "status":"diverged","steps_done":40,"wall_ms":500,
                "final_train_loss":null,"final_test_loss":null,
                "final_test_err":null,"best_test_err":31,
                "diverged_at":40,"error":null,
                "numerics":{"first_nonfinite_step":38,"elems":1000,
                            "sat_rate":0.01,"underflow_rate":0.0,
                            "layers":[{"name":"fc1/grad","elems":500,
                                       "sat_rate":0.2125,"underflow_rate":0.0}]}}"#,
        )
        .unwrap();
        let mut records = BTreeMap::new();
        records.insert("a".to_string(), rec_done);
        records.insert("b".to_string(), rec_div);

        let md = render_report("SWEEP.json", &records, false);
        let want = "\
# Sweep report: SWEEP.json

2 cells (artifact schema 3).

| model | fmt | round | pos | opt | chunk | status | final err % | best err % | wall ms | notes |
|---|---|---|---|---|---|---|---|---|---|---|
| mnist_dnn | fp8_paper | default | auto | sgd | 64 | done | 2.375 | 2.250 | 1234 |  |
| mnist_dnn | e4m3 | default | auto | sgd | 64 | diverged | - | 31.000 | 500 | diverged at step 40; top saturating layer fc1/grad (21.25% sat) |

**Summary:** 1 done, 1 diverged, 0 failed, 0 timed out.
";
        assert_eq!(md, want);

        let csv = render_report("SWEEP.json", &records, true);
        let want_csv = "\
id,model,fmt,round,pos,opt,chunk,status,steps_done,final_test_err,best_test_err,wall_ms,note
a,mnist_dnn,fp8_paper,default,auto,sgd,64,done,100,2.375,2.250,1234,\"\"
b,mnist_dnn,e4m3,default,auto,sgd,64,diverged,40,-,31.000,500,\"diverged at step 40; top saturating layer fc1/grad (21.25% sat)\"
";
        assert_eq!(csv, want_csv);
    }

    #[test]
    fn expansion_is_deterministic_and_ordered() {
        let def = tiny_def();
        let a = expand(&def).unwrap();
        let b = expand(&def).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        // Model axis varies slowest, format axis inside it.
        let ids: Vec<String> = a.iter().map(Cell::id).collect();
        assert!(ids[0].starts_with("in(6)-fc(4)-relu-fc(3)|fmt=fp32|"), "{}", ids[0]);
        assert!(ids[1].starts_with("in(6)-fc(4)-relu-fc(3)|fmt=fp8_paper|"), "{}", ids[1]);
        assert!(ids[2].starts_with("in(6)-fc(5)-relu-fc(3)|fmt=fp32|"), "{}", ids[2]);
        // Budget knobs are part of the id: changing steps re-keys the grid.
        let mut def2 = tiny_def();
        def2.steps = 3;
        let c = expand(&def2).unwrap();
        assert_ne!(ids[0], c[0].id());
    }

    #[test]
    fn expansion_validates_every_axis_value_up_front() {
        for (mutate, why) in [
            ((|d: &mut SweepDef| d.formats.push("warp9".into())) as fn(&mut SweepDef), "bad format"),
            (|d: &mut SweepDef| d.rounds.push("sideways".into()), "bad round"),
            (|d: &mut SweepDef| d.pos.push("beside".into()), "bad pos"),
            (|d: &mut SweepDef| d.opts.push("lbfgs".into()), "bad opt"),
            (|d: &mut SweepDef| d.formats.push("fp32".into()), "duplicate format"),
            (|d: &mut SweepDef| d.template = "mlp(6,{4,4},3)".into(), "aliasing models"),
            (|d: &mut SweepDef| d.template = "warp({1,2})".into(), "bad template"),
            (|d: &mut SweepDef| d.steps = 0, "zero steps"),
            (|d: &mut SweepDef| d.chunks = vec![], "empty chunk axis"),
        ] {
            let mut def = tiny_def();
            mutate(&mut def);
            assert!(expand(&def).is_err(), "{why} should fail expansion");
        }
        // A pos override that no expansion supports fails at expand time.
        let mut def = tiny_def();
        def.template = "in(3x4x4)-gap".into();
        def.pos = vec!["last".into()];
        assert!(expand(&def).is_err());
        // Alias spellings resolve to the same axis value: rejected, not
        // trained twice under distinct ids.
        let mut def = tiny_def();
        def.formats = vec!["e4m3".into(), "1-4-3".into()];
        assert!(expand(&def).is_err(), "aliased format spellings");
        let mut def = tiny_def();
        def.rounds = vec!["stochastic".into(), "sr".into()];
        assert!(expand(&def).is_err(), "aliased round spellings");
        // Seeds beyond f64's exact-integer range would corrupt on the
        // parse→dump canonicalization: refused up front.
        let mut def = tiny_def();
        def.seed = u64::MAX;
        assert!(expand(&def).is_err(), "seed beyond 2^53");
    }

    #[test]
    fn format_axis_accepts_presets_and_bare_formats() {
        assert_eq!(resolve_policy("fp32").unwrap().name, "fp32");
        assert_eq!(resolve_policy("dorefa").unwrap().name, "dorefa");
        let p = resolve_policy("e4m3").unwrap();
        assert_eq!(p.name, "paper_e4m3");
        assert_eq!(
            p.gemm[0].fmt_mult,
            FloatFormat { ebits: 4, mbits: 3 }
        );
        // Last layer keeps the paper's FP16 rule.
        assert_eq!(p.gemm_last[0].fmt_mult, FloatFormat::FP16);
        assert!(resolve_policy("zz9").is_err());
    }

    #[test]
    fn format_axis_accepts_inline_json_policies() {
        let p = resolve_policy(r#"{"name":"hot","fmt":"e4m3","chunk":32}"#).unwrap();
        assert_eq!(p.name, "hot");
        assert_eq!(p.gemm[0].fmt_mult, FloatFormat { ebits: 4, mbits: 3 });
        assert_eq!(p.gemm[0].chunk, 32);
        // Errors surface with the from_json message, not the preset list.
        let err = resolve_policy(r#"{"name":"x","fmt":"zz"}"#).unwrap_err();
        assert!(err.to_string().contains("unknown float format"), "{err}");
    }

    #[test]
    fn policy_json_tokens_load_validate_and_rekey() {
        // One object and an array both work; tokens are compact dumps.
        let one = policy_json_tokens(r#"{"name":"a","chunk":16}"#).unwrap();
        assert_eq!(one, vec![r#"{"chunk":16,"name":"a"}"#.to_string()]);
        let two = policy_json_tokens(
            r#"[{"name":"a","chunk":16},
                {"name":"b","base":"fp32","fmt":"bf16"}]"#,
        )
        .unwrap();
        assert_eq!(two.len(), 2);
        // Tokens slot into the format axis and key cells on content: the
        // cell id embeds the JSON, so editing a knob re-keys the grid.
        let mut def = tiny_def();
        def.formats = two.clone();
        let cells = expand(&def).unwrap();
        assert!(cells[0].id().contains(r#"fmt={"chunk":16,"name":"a"}"#), "{}", cells[0].id());
        let mut def2 = tiny_def();
        def2.formats = vec![two[0].replace("16", "32"), two[1].clone()];
        assert_ne!(expand(&def2).unwrap()[0].id(), cells[0].id());
        // Formatting-only edits (whitespace, key order) do NOT re-key:
        // dump() canonicalizes before the token enters the id.
        let same = policy_json_tokens(r#"{ "chunk" : 16, "name" : "a" }"#).unwrap();
        assert_eq!(same, one);
        // Invalid files fail up front.
        assert!(policy_json_tokens("[]").is_err());
        assert!(policy_json_tokens("42").is_err());
        assert!(policy_json_tokens(r#"{"name":"fp32"}"#).is_err(), "preset shadowing");
        // Duplicate policy *names* across tokens collide in the CSV/report
        // keying: expansion's resolved-name dedup rejects them.
        let mut def3 = tiny_def();
        def3.formats = vec![
            r#"{"name":"a","chunk":16}"#.into(),
            r#"{"name":"a","chunk":32}"#.into(),
        ];
        assert!(expand(&def3).is_err(), "same resolved name must be rejected");
    }

    #[test]
    fn cell_records_and_artifact_are_valid_json() {
        let cells = expand(&tiny_def()).unwrap();
        let phases = PhaseSnapshot::default();
        // A cell with no result (NaN-free nulls) and one with a NaN curve
        // both serialize to parseable JSON.
        let rec = cell_json(&cells[0], "timeout", 1, 12.5, None, &phases, 1, 5, None, None, None);
        let v = Json::parse(&rec).unwrap();
        assert_eq!(v.at("status").and_then(Json::str_val), Some("timeout"));
        assert_eq!(v.at("final_test_err"), Some(&Json::Null));
        assert_eq!(v.at("numerics"), Some(&Json::Null));
        let r = TrainResult {
            curve: vec![crate::train::EvalPoint {
                step: 2,
                train_loss: f64::NAN,
                test_loss: 1.5,
                test_err: 50.0,
            }],
            final_test_err: 50.0,
            final_train_loss: f64::NAN,
            diverged_at: None,
        };
        let numerics = crate::telemetry::numerics_summary_json();
        let rec = cell_json(
            &cells[1],
            "done",
            2,
            3.25,
            Some(&r),
            &phases,
            2,
            5,
            None,
            None,
            Some(&numerics),
        );
        let v = Json::parse(&rec).unwrap();
        assert_eq!(v.at("final_train_loss"), Some(&Json::Null));
        // The numerics summary nests as an object with its documented keys.
        assert!(v.at("numerics.elems").and_then(Json::num).is_some(), "{rec}");
        assert!(v.at("numerics.layers").is_some(), "{rec}");
        assert_eq!(v.at("curve_tail.0.test_err").and_then(Json::num), Some(50.0));
        assert_eq!(v.at("id").and_then(Json::str_val), Some(cells[1].id().as_str()));
    }

    #[test]
    fn artifact_write_load_round_trips() {
        let dir = std::env::temp_dir().join("fp8train_sweep_artifact");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("SWEEP.json").to_string_lossy().into_owned();
        let def = tiny_def();
        let cells = expand(&def).unwrap();
        let phases = PhaseSnapshot::default();
        let recs: Vec<String> = cells
            .iter()
            .map(|c| cell_json(c, "done", 2, 1.0, None, &phases, 2, 5, None, None, None))
            .collect();
        write_artifact(&path, &def, &recs).unwrap();
        let loaded = load_artifact(&path).unwrap();
        assert_eq!(loaded.len(), 4);
        for c in &cells {
            let rec = &loaded[&c.id()];
            assert_eq!(rec.at("status").and_then(Json::str_val), Some("done"));
        }
        // A garbage artifact is an error, not an overwrite.
        std::fs::write(&path, "not json").unwrap();
        assert!(load_artifact(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checkpoint_hash_is_stable() {
        // Cell checkpoint file names are keyed by the crate's shared
        // layer-hash (`nn::linear::layer_hash`, an FNV-1a variant) over
        // the cell id; pin its vectors so resumable checkpoints never
        // silently re-key between builds. (Note: its multiplier is
        // 0x1000000001b3 — not the textbook FNV prime — and is frozen:
        // it also seeds the per-layer SR streams.)
        assert_eq!(layer_hash(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(layer_hash("a"), 0xaf74_d84c_8601_ec8c);
        assert_ne!(layer_hash("cell_a"), layer_hash("cell_b"));
    }

    #[test]
    fn timeout_record_wall_time_accumulates() {
        // run_cell adds the prior (interrupted) invocation's wall_ms, so a
        // resumed cell's record reports total wall time across resumes.
        let cells = expand(&tiny_def()).unwrap();
        let phases = PhaseSnapshot::default();
        let rec = cell_json(
            &cells[0],
            "timeout",
            1,
            1500.0 + 12.5,
            None,
            &phases,
            1,
            5,
            None,
            None,
            None,
        );
        let v = Json::parse(&rec).unwrap();
        assert_eq!(v.at("wall_ms").and_then(Json::num), Some(1512.5));
    }

    #[test]
    fn diverged_and_error_fields_serialize() {
        let cells = expand(&tiny_def()).unwrap();
        let phases = PhaseSnapshot::default();
        let rec =
            cell_json(&cells[0], "diverged", 7, 0.0, None, &phases, 0, 5, Some(7), None, None);
        let v = Json::parse(&rec).unwrap();
        assert_eq!(v.at("status").and_then(Json::str_val), Some("diverged"));
        assert_eq!(v.at("diverged_at").and_then(Json::num), Some(7.0));
        assert_eq!(v.at("error"), Some(&Json::Null));
        let rec = cell_json(
            &cells[0],
            "failed",
            2,
            1.0,
            None,
            &phases,
            0,
            5,
            None,
            Some("exit status 3"),
            None,
        );
        let v = Json::parse(&rec).unwrap();
        assert_eq!(v.at("error").and_then(Json::str_val), Some("exit status 3"));
        assert_eq!(v.at("diverged_at"), Some(&Json::Null));
    }
}
