//! Bench-report comparison: a zero-dependency JSON reader plus the
//! per-metric delta logic behind `fp8train bench --compare <old.json>`.
//!
//! The repo carries no `serde` (offline, zero external crates), so this
//! module implements the small JSON subset `BENCH_GEMM.json` needs:
//! objects, arrays, strings (with escapes), f64 numbers, booleans and
//! null. On top of it, [`compare`] extracts the tracked throughput
//! metrics from two reports (schema 3 and 4 share the shapes/scratch/
//! checkpoint layout) and classifies each delta — the CI bench job runs
//! this against the committed baseline so the perf trajectory is a
//! *checked* number, not just an uploaded artifact.
//!
//! The reader/writer pair ([`Json::parse`] / [`Json::dump`] + [`escape`])
//! is also the substrate of the sweep artifact (`SWEEP.json`,
//! `docs/sweep.md`) and its `sweep diff` comparator, so the parser is
//! hardened to be *total* over arbitrary files: truncated input, garbage,
//! and pathological nesting (bounded by [`MAX_DEPTH`]) return `Err` —
//! never a panic or a stack overflow.

use std::collections::BTreeMap;

/// A parsed JSON value (the subset the bench reports use).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Containers deeper than this are rejected with an `Err` instead of
/// recursing toward a stack overflow — malformed/adversarial inputs (e.g.
/// `"[".repeat(1 << 20)`) must never abort the process. Every report this
/// crate emits nests < 10 deep.
const MAX_DEPTH: usize = 128;

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    ///
    /// Total: every input — truncated, deeply nested, garbage — returns
    /// `Ok` or `Err`, never panics (the `sweep diff`/`bench compare`
    /// comparators feed this user-supplied files).
    pub fn parse(text: &str) -> Result<Json, String> {
        let b = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos, 0)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }

    /// Serialize back to compact JSON text that re-parses to an equal
    /// value. Non-finite numbers (never produced by the parser, but
    /// constructible) serialize as `null` so the output is always valid
    /// JSON. Used by the sweep runner to carry completed-cell records from
    /// an existing artifact into the merged one verbatim.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.dump_into(&mut out);
        out
    }

    fn dump_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.dump_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\":");
                    v.dump_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Walk a `.`-separated path of object keys / array indices.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn str_val(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// JSON string escaping for the hand-rolled writers ([`Json::dump`] and
/// the sweep artifact emitter): quotes, backslashes and control characters
/// become escapes; everything else passes through as UTF-8.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    if depth > MAX_DEPTH {
        return Err(format!(
            "nesting deeper than {MAX_DEPTH} at byte {pos}",
            pos = *pos
        ));
    }
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos, depth + 1)? {
                    Json::Str(s) => s,
                    other => return Err(format!("object key must be a string, got {other:?}")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}", pos = *pos));
                }
                *pos += 1;
                m.insert(key, parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut a = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(a));
            }
            loop {
                a.push(parse_value(b, pos, depth + 1)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(a));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'"') => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => return Err("unterminated string".into()),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    Some(b'\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'r') => s.push('\r'),
                            Some(b'b') => s.push('\u{8}'),
                            Some(b'f') => s.push('\u{c}'),
                            Some(b'u') => {
                                let hex = b
                                    .get(*pos + 1..*pos + 5)
                                    .ok_or("truncated \\u escape")?;
                                let code = u32::from_str_radix(
                                    std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                    16,
                                )
                                .map_err(|e| e.to_string())?;
                                // Bench names never contain surrogate
                                // pairs; map unpaired surrogates to U+FFFD.
                                s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                                *pos += 4;
                            }
                            other => return Err(format!("bad escape {other:?}")),
                        }
                        *pos += 1;
                    }
                    Some(&c) => {
                        // Copy raw UTF-8 bytes through.
                        let chunk = b
                            .get(*pos..*pos + utf8_len(c))
                            .ok_or("truncated UTF-8 sequence")?;
                        s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                        *pos += utf8_len(c);
                    }
                }
            }
        }
        Some(b't') => lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => lit(b, pos, "null", Json::Null),
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number {s:?} at byte {start}"))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}", pos = *pos))
    }
}

/// Direction of a tracked metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Better {
    Higher,
    Lower,
}

/// One compared metric.
#[derive(Clone, Debug)]
pub struct Delta {
    pub name: String,
    pub old: f64,
    pub new: f64,
    pub better: Better,
}

impl Delta {
    /// Signed change in percent, oriented so positive = improvement.
    pub fn improvement_pct(&self) -> f64 {
        if self.old == 0.0 {
            return 0.0;
        }
        let raw = (self.new - self.old) / self.old * 100.0;
        match self.better {
            Better::Higher => raw,
            Better::Lower => -raw,
        }
    }

    /// Regression beyond `threshold_pct`?
    pub fn regressed(&self, threshold_pct: f64) -> bool {
        self.improvement_pct() < -threshold_pct
    }
}

/// Pull the tracked `(path, direction)` metric set out of one report.
/// Shared by both sides of the comparison so only mutually-present
/// metrics are compared (schema drift degrades to a narrower table, not
/// an error).
fn metrics(doc: &Json) -> Vec<(String, f64, Better)> {
    let mut out = Vec::new();
    if let Some(Json::Arr(shapes)) = doc.at("shapes") {
        for shape in shapes {
            let label = shape
                .at("label")
                .and_then(Json::str_val)
                .unwrap_or("?")
                .to_string();
            if let Some(Json::Obj(paths)) = shape.at("paths") {
                for (pname, p) in paths {
                    if let Some(v) = p.at("gmacs_per_sec").and_then(Json::num) {
                        out.push((format!("gemm/{label}/{pname} GMAC/s"), v, Better::Higher));
                    }
                }
            }
        }
    }
    if let Some(v) = doc.at("scratch.train_step.mean_ns").and_then(Json::num) {
        out.push(("train_step mean_ns".into(), v, Better::Lower));
    }
    for ck in ["encode", "decode_restore"] {
        if let Some(v) = doc
            .at(&format!("checkpoint.paths.{ck}.mb_per_sec"))
            .and_then(Json::num)
        {
            out.push((format!("checkpoint/{ck} MB/s"), v, Better::Higher));
        }
    }
    out
}

/// Compare two bench reports; returns the per-metric deltas for every
/// metric present in both (empty when the baseline is a bootstrap stub).
pub fn compare(old: &Json, new: &Json) -> Vec<Delta> {
    let old_m: BTreeMap<String, (f64, Better)> = metrics(old)
        .into_iter()
        .map(|(n, v, b)| (n, (v, b)))
        .collect();
    metrics(new)
        .into_iter()
        .filter_map(|(name, new_v, better)| {
            old_m.get(&name).map(|&(old_v, _)| Delta {
                name,
                old: old_v,
                new: new_v,
                better,
            })
        })
        .collect()
}

/// Render the comparison table; returns the regressed metric names
/// (> `threshold_pct` worse than the baseline).
pub fn report(deltas: &[Delta], threshold_pct: f64) -> Vec<String> {
    let mut regressed = Vec::new();
    println!(
        "{:<44} {:>14} {:>14} {:>9}",
        "metric", "baseline", "current", "delta"
    );
    for d in deltas {
        let pct = d.improvement_pct();
        let flag = if d.regressed(threshold_pct) {
            regressed.push(d.name.clone());
            "  REGRESSED"
        } else {
            ""
        };
        println!(
            "{:<44} {:>14.4} {:>14.4} {:>+8.1}%{flag}",
            d.name, d.old, d.new, pct
        );
    }
    regressed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_arrays_objects() {
        let v = Json::parse(r#"{"a":[1,2.5,-3e2],"b":{"c":"x\n\"y\""},"d":true,"e":null}"#)
            .unwrap();
        assert_eq!(v.at("a.1").unwrap().num(), Some(2.5));
        assert_eq!(v.at("a.2").unwrap().num(), Some(-300.0));
        assert_eq!(v.at("b.c").unwrap().str_val(), Some("x\n\"y\""));
        assert_eq!(v.at("d"), Some(&Json::Bool(true)));
        assert_eq!(v.at("e"), Some(&Json::Null));
        assert!(v.at("nope").is_none());
        assert!(v.at("a.9").is_none());
    }

    #[test]
    fn parses_unicode_escapes_and_rejects_garbage() {
        let v = Json::parse(r#"{"s":"Aé"}"#).unwrap();
        assert_eq!(v.at("s").unwrap().str_val(), Some("Aé"));
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn exponent_and_signed_zero_numbers_parse_exactly() {
        // The sweep comparator feeds this arbitrary artifact files, so the
        // number grammar corners must parse (or Err) — never panic.
        let v = Json::parse(r#"[1e2,1E2,1.5e-3,2e+4,-0.0,-0,0.25,123456789.0]"#).unwrap();
        assert_eq!(v.at("0").unwrap().num(), Some(100.0));
        assert_eq!(v.at("1").unwrap().num(), Some(100.0));
        assert_eq!(v.at("2").unwrap().num(), Some(0.0015));
        assert_eq!(v.at("3").unwrap().num(), Some(20000.0));
        // Negative zero keeps its sign bit through parse and dump.
        let nz = v.at("4").unwrap().num().unwrap();
        assert_eq!(nz, 0.0);
        assert!(nz.is_sign_negative(), "-0.0 lost its sign");
        assert_eq!(v.at("5").unwrap().num().map(f64::is_sign_negative), Some(true));
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(back.at("4").unwrap().num().map(f64::is_sign_negative), Some(true));
        // Malformed exponent/sign forms are errors, not panics.
        for bad in ["1e", "1e+", "--1", "+-2", "1.2.3", ".", "-", "e5"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn deep_nesting_returns_err_not_stack_overflow() {
        // 128 levels is fine; tens of thousands used to recurse the parser
        // off the stack (process abort, not an Err).
        let ok = format!("{}0{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        let deep_arr = "[".repeat(100_000);
        assert!(Json::parse(&deep_arr).is_err());
        let deep_obj = "{\"k\":".repeat(100_000);
        assert!(Json::parse(&deep_obj).is_err());
        let over = format!("{}0{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert!(Json::parse(&over).is_err());
    }

    #[test]
    fn truncated_inputs_return_err() {
        for bad in [
            "",
            "{",
            "[1,2",
            r#"{"a""#,
            r#"{"a":"#,
            r#"{"a":1,"#,
            r#""unterminated"#,
            r#""bad \u00"#,
            r#""bad \"#,
            "tru",
            "nul",
            "[1,2,",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should be an error");
        }
    }

    #[test]
    fn dump_round_trips_escapes_and_structure() {
        let src = r#"{"a":[1,2.5,null,true],"s":"x\n\"y\"\\z","n":-0.125,"o":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
        // Control characters escape on the way out.
        let s = Json::Str("a\u{1}\tb".into());
        assert_eq!(s.dump(), "\"a\\u0001\\tb\"");
        assert_eq!(Json::parse(&s.dump()).unwrap(), s);
        // Non-finite constructed numbers degrade to null, not invalid JSON.
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn parses_a_real_bench_result_fragment() {
        // The exact shape bench_util::BenchResult::to_json emits.
        let frag = r#"{"name":"bench/x/\"odd\"","iters":10,"mean_ns":1500,"p50_ns":1400,"p99_ns":2000,"units_per_iter":1.000000e2,"units_per_sec":6.666667e7}"#;
        let v = Json::parse(frag).unwrap();
        assert_eq!(v.at("mean_ns").unwrap().num(), Some(1500.0));
        assert_eq!(v.at("units_per_sec").unwrap().num(), Some(6.666667e7));
        assert_eq!(v.at("name").unwrap().str_val(), Some("bench/x/\"odd\""));
    }

    fn doc(gmacs: f64, step_ns: f64, enc: f64) -> Json {
        Json::parse(&format!(
            r#"{{"schema":4,"shapes":[{{"label":"sq","m":1,"k":1,"n":1,
                "paths":{{"fp32":{{"gmacs_per_sec":{gmacs}}}}}}}],
                "scratch":{{"train_step":{{"mean_ns":{step_ns}}}}},
                "checkpoint":{{"paths":{{"encode":{{"mb_per_sec":{enc}}}}}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn compare_classifies_improvements_and_regressions() {
        let old = doc(10.0, 1000.0, 50.0);
        // GEMM 20% faster, train step 20% slower, encode unchanged.
        let new = doc(12.0, 1200.0, 50.0);
        let deltas = compare(&old, &new);
        assert_eq!(deltas.len(), 3);
        let by_name = |n: &str| deltas.iter().find(|d| d.name.contains(n)).unwrap();
        assert!(by_name("gemm").improvement_pct() > 19.0);
        assert!(!by_name("gemm").regressed(10.0));
        assert!(by_name("train_step").regressed(10.0));
        assert!(!by_name("encode").regressed(10.0));
        // 10% threshold is exclusive: a 5% slip is not a regression.
        let mild = doc(9.5, 1000.0, 50.0);
        assert!(!compare(&old, &mild)[0].regressed(10.0));
    }

    #[test]
    fn bootstrap_baseline_compares_empty() {
        let old = Json::parse(r#"{"schema":4,"bootstrap":true}"#).unwrap();
        let new = doc(10.0, 1000.0, 50.0);
        assert!(compare(&old, &new).is_empty());
    }
}
