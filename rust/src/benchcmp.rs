//! Bench-report comparison: a zero-dependency JSON reader plus the
//! per-metric delta logic behind `fp8train bench --compare <old.json>`.
//!
//! The repo carries no `serde` (offline, zero external crates), so this
//! module implements the small JSON subset `BENCH_GEMM.json` needs:
//! objects, arrays, strings (with escapes), f64 numbers, booleans and
//! null. On top of it, [`compare`] extracts the tracked throughput
//! metrics from two reports (schema 3 and 4 share the shapes/scratch/
//! checkpoint layout) and classifies each delta — the CI bench job runs
//! this against the committed baseline so the perf trajectory is a
//! *checked* number, not just an uploaded artifact.

use std::collections::BTreeMap;

/// A parsed JSON value (the subset the bench reports use).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, String> {
        let b = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }

    /// Walk a `.`-separated path of object keys / array indices.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = match cur {
                Json::Obj(m) => m.get(part)?,
                Json::Arr(a) => a.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn str_val(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = match parse_value(b, pos)? {
                    Json::Str(s) => s,
                    other => return Err(format!("object key must be a string, got {other:?}")),
                };
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}", pos = *pos));
                }
                *pos += 1;
                m.insert(key, parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'[') => {
            *pos += 1;
            let mut a = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(a));
            }
            loop {
                a.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(a));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'"') => {
            *pos += 1;
            let mut s = String::new();
            loop {
                match b.get(*pos) {
                    None => return Err("unterminated string".into()),
                    Some(b'"') => {
                        *pos += 1;
                        return Ok(Json::Str(s));
                    }
                    Some(b'\\') => {
                        *pos += 1;
                        match b.get(*pos) {
                            Some(b'"') => s.push('"'),
                            Some(b'\\') => s.push('\\'),
                            Some(b'/') => s.push('/'),
                            Some(b'n') => s.push('\n'),
                            Some(b't') => s.push('\t'),
                            Some(b'r') => s.push('\r'),
                            Some(b'b') => s.push('\u{8}'),
                            Some(b'f') => s.push('\u{c}'),
                            Some(b'u') => {
                                let hex = b
                                    .get(*pos + 1..*pos + 5)
                                    .ok_or("truncated \\u escape")?;
                                let code = u32::from_str_radix(
                                    std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                    16,
                                )
                                .map_err(|e| e.to_string())?;
                                // Bench names never contain surrogate
                                // pairs; map unpaired surrogates to U+FFFD.
                                s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                                *pos += 4;
                            }
                            other => return Err(format!("bad escape {other:?}")),
                        }
                        *pos += 1;
                    }
                    Some(&c) => {
                        // Copy raw UTF-8 bytes through.
                        let chunk = b
                            .get(*pos..*pos + utf8_len(c))
                            .ok_or("truncated UTF-8 sequence")?;
                        s.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                        *pos += utf8_len(c);
                    }
                }
            }
        }
        Some(b't') => lit(b, pos, "true", Json::Bool(true)),
        Some(b'f') => lit(b, pos, "false", Json::Bool(false)),
        Some(b'n') => lit(b, pos, "null", Json::Null),
        Some(_) => {
            let start = *pos;
            while *pos < b.len()
                && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *pos += 1;
            }
            let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|_| format!("bad number {s:?} at byte {start}"))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}", pos = *pos))
    }
}

/// Direction of a tracked metric.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Better {
    Higher,
    Lower,
}

/// One compared metric.
#[derive(Clone, Debug)]
pub struct Delta {
    pub name: String,
    pub old: f64,
    pub new: f64,
    pub better: Better,
}

impl Delta {
    /// Signed change in percent, oriented so positive = improvement.
    pub fn improvement_pct(&self) -> f64 {
        if self.old == 0.0 {
            return 0.0;
        }
        let raw = (self.new - self.old) / self.old * 100.0;
        match self.better {
            Better::Higher => raw,
            Better::Lower => -raw,
        }
    }

    /// Regression beyond `threshold_pct`?
    pub fn regressed(&self, threshold_pct: f64) -> bool {
        self.improvement_pct() < -threshold_pct
    }
}

/// Pull the tracked `(path, direction)` metric set out of one report.
/// Shared by both sides of the comparison so only mutually-present
/// metrics are compared (schema drift degrades to a narrower table, not
/// an error).
fn metrics(doc: &Json) -> Vec<(String, f64, Better)> {
    let mut out = Vec::new();
    if let Some(Json::Arr(shapes)) = doc.at("shapes") {
        for shape in shapes {
            let label = shape
                .at("label")
                .and_then(Json::str_val)
                .unwrap_or("?")
                .to_string();
            if let Some(Json::Obj(paths)) = shape.at("paths") {
                for (pname, p) in paths {
                    if let Some(v) = p.at("gmacs_per_sec").and_then(Json::num) {
                        out.push((format!("gemm/{label}/{pname} GMAC/s"), v, Better::Higher));
                    }
                }
            }
        }
    }
    if let Some(v) = doc.at("scratch.train_step.mean_ns").and_then(Json::num) {
        out.push(("train_step mean_ns".into(), v, Better::Lower));
    }
    for ck in ["encode", "decode_restore"] {
        if let Some(v) = doc
            .at(&format!("checkpoint.paths.{ck}.mb_per_sec"))
            .and_then(Json::num)
        {
            out.push((format!("checkpoint/{ck} MB/s"), v, Better::Higher));
        }
    }
    out
}

/// Compare two bench reports; returns the per-metric deltas for every
/// metric present in both (empty when the baseline is a bootstrap stub).
pub fn compare(old: &Json, new: &Json) -> Vec<Delta> {
    let old_m: BTreeMap<String, (f64, Better)> = metrics(old)
        .into_iter()
        .map(|(n, v, b)| (n, (v, b)))
        .collect();
    metrics(new)
        .into_iter()
        .filter_map(|(name, new_v, better)| {
            old_m.get(&name).map(|&(old_v, _)| Delta {
                name,
                old: old_v,
                new: new_v,
                better,
            })
        })
        .collect()
}

/// Render the comparison table; returns the regressed metric names
/// (> `threshold_pct` worse than the baseline).
pub fn report(deltas: &[Delta], threshold_pct: f64) -> Vec<String> {
    let mut regressed = Vec::new();
    println!(
        "{:<44} {:>14} {:>14} {:>9}",
        "metric", "baseline", "current", "delta"
    );
    for d in deltas {
        let pct = d.improvement_pct();
        let flag = if d.regressed(threshold_pct) {
            regressed.push(d.name.clone());
            "  REGRESSED"
        } else {
            ""
        };
        println!(
            "{:<44} {:>14.4} {:>14.4} {:>+8.1}%{flag}",
            d.name, d.old, d.new, pct
        );
    }
    regressed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_arrays_objects() {
        let v = Json::parse(r#"{"a":[1,2.5,-3e2],"b":{"c":"x\n\"y\""},"d":true,"e":null}"#)
            .unwrap();
        assert_eq!(v.at("a.1").unwrap().num(), Some(2.5));
        assert_eq!(v.at("a.2").unwrap().num(), Some(-300.0));
        assert_eq!(v.at("b.c").unwrap().str_val(), Some("x\n\"y\""));
        assert_eq!(v.at("d"), Some(&Json::Bool(true)));
        assert_eq!(v.at("e"), Some(&Json::Null));
        assert!(v.at("nope").is_none());
        assert!(v.at("a.9").is_none());
    }

    #[test]
    fn parses_unicode_escapes_and_rejects_garbage() {
        let v = Json::parse(r#"{"s":"Aé"}"#).unwrap();
        assert_eq!(v.at("s").unwrap().str_val(), Some("Aé"));
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn parses_a_real_bench_result_fragment() {
        // The exact shape bench_util::BenchResult::to_json emits.
        let frag = r#"{"name":"bench/x/\"odd\"","iters":10,"mean_ns":1500,"p50_ns":1400,"p99_ns":2000,"units_per_iter":1.000000e2,"units_per_sec":6.666667e7}"#;
        let v = Json::parse(frag).unwrap();
        assert_eq!(v.at("mean_ns").unwrap().num(), Some(1500.0));
        assert_eq!(v.at("units_per_sec").unwrap().num(), Some(6.666667e7));
        assert_eq!(v.at("name").unwrap().str_val(), Some("bench/x/\"odd\""));
    }

    fn doc(gmacs: f64, step_ns: f64, enc: f64) -> Json {
        Json::parse(&format!(
            r#"{{"schema":4,"shapes":[{{"label":"sq","m":1,"k":1,"n":1,
                "paths":{{"fp32":{{"gmacs_per_sec":{gmacs}}}}}}}],
                "scratch":{{"train_step":{{"mean_ns":{step_ns}}}}},
                "checkpoint":{{"paths":{{"encode":{{"mb_per_sec":{enc}}}}}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn compare_classifies_improvements_and_regressions() {
        let old = doc(10.0, 1000.0, 50.0);
        // GEMM 20% faster, train step 20% slower, encode unchanged.
        let new = doc(12.0, 1200.0, 50.0);
        let deltas = compare(&old, &new);
        assert_eq!(deltas.len(), 3);
        let by_name = |n: &str| deltas.iter().find(|d| d.name.contains(n)).unwrap();
        assert!(by_name("gemm").improvement_pct() > 19.0);
        assert!(!by_name("gemm").regressed(10.0));
        assert!(by_name("train_step").regressed(10.0));
        assert!(!by_name("encode").regressed(10.0));
        // 10% threshold is exclusive: a 5% slip is not a regression.
        let mild = doc(9.5, 1000.0, 50.0);
        assert!(!compare(&old, &mild)[0].regressed(10.0));
    }

    #[test]
    fn bootstrap_baseline_compares_empty() {
        let old = Json::parse(r#"{"schema":4,"bootstrap":true}"#).unwrap();
        let new = doc(10.0, 1000.0, 50.0);
        assert!(compare(&old, &new).is_empty());
    }
}
