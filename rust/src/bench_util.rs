//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the binaries under `rust/benches/` with
//! `harness = false`; each uses this module: auto-calibrated iteration
//! counts, warmup, and trimmed statistics (mean / p50 / p99), printed in a
//! stable machine-parseable format that EXPERIMENTS.md quotes.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    /// Optional work units per iteration (e.g. FLOPs, elements) for
    /// throughput reporting.
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    /// Work units per second, if `units_per_iter` was supplied.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter
            .map(|u| u / self.mean.as_secs_f64())
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "bench {:<48} iters={:<7} mean={:>12?} p50={:>12?} p99={:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p99
        )?;
        if let Some(t) = self.throughput() {
            write!(f, " thrpt={}", human_rate(t))?;
        }
        Ok(())
    }
}

impl BenchResult {
    /// Render this result as a JSON object (no `serde` offline; names are
    /// escaped, so the output is always valid JSON). Times in nanoseconds.
    pub fn to_json(&self) -> String {
        let opt = |v: Option<f64>| v.map(|u| format!("{u:.6e}")).unwrap_or_else(|| "null".into());
        format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_ns\":{},\"p50_ns\":{},\"p99_ns\":{},\"units_per_iter\":{},\"units_per_sec\":{}}}",
            json_escape(&self.name),
            self.iters,
            self.mean.as_nanos(),
            self.p50.as_nanos(),
            self.p99.as_nanos(),
            opt(self.units_per_iter),
            opt(self.throughput()),
        )
    }
}

/// Escape a string for embedding in a JSON document. Delegates to the
/// crate's single writer-side escaper ([`crate::benchcmp::escape`]) so
/// the bench and sweep artifacts can never drift apart in encoding.
pub fn json_escape(s: &str) -> String {
    crate::benchcmp::escape(s)
}

fn human_rate(r: f64) -> String {
    if r >= 1e9 {
        format!("{:.2}G/s", r / 1e9)
    } else if r >= 1e6 {
        format!("{:.2}M/s", r / 1e6)
    } else if r >= 1e3 {
        format!("{:.2}K/s", r / 1e3)
    } else {
        format!("{r:.2}/s")
    }
}

/// Benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Minimum total measurement time.
    pub min_time: Duration,
    /// Warmup time before measuring.
    pub warmup: Duration,
    /// Upper bound on measured samples (keeps percentile math bounded).
    pub max_samples: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        // FP8TRAIN_BENCH_FAST=1 shrinks budgets ~10x (CI / smoke runs).
        let fast = std::env::var("FP8TRAIN_BENCH_FAST").is_ok();
        Self {
            min_time: Duration::from_millis(if fast { 60 } else { 600 }),
            warmup: Duration::from_millis(if fast { 20 } else { 200 }),
            max_samples: 10_000,
        }
    }
}

/// Measure `f`, which performs ONE iteration of work and returns a value
/// that is black-boxed to stop the optimizer deleting the work.
pub fn bench<T, F: FnMut() -> T>(name: &str, units_per_iter: Option<f64>, mut f: F) -> BenchResult {
    let opts = BenchOpts::default();
    // Warmup & calibration.
    let warm_start = Instant::now();
    let mut warm_iters = 0usize;
    while warm_start.elapsed() < opts.warmup || warm_iters < 3 {
        std::hint::black_box(f());
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed() / warm_iters as u32;
    // Batch iterations so each sample is ≥ ~20 µs (timer noise floor).
    let batch = (Duration::from_micros(20).as_nanos() / per_iter.as_nanos().max(1))
        .max(1)
        .min(1 << 20) as usize;

    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    let mut total_iters = 0usize;
    while start.elapsed() < opts.min_time && samples.len() < opts.max_samples {
        let t = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed() / batch as u32);
        total_iters += batch;
    }
    samples.sort_unstable();
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    // Trimmed mean: drop top/bottom 5%.
    let lo = samples.len() / 20;
    let hi = samples.len() - lo;
    let mean = samples[lo..hi]
        .iter()
        .sum::<Duration>()
        .checked_div((hi - lo) as u32)
        .unwrap_or_default();
    BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean,
        p50: p(0.5),
        p99: p(0.99),
        units_per_iter,
    }
}

/// Run + print, returning the result for programmatic use.
pub fn run(name: &str, units_per_iter: Option<f64>, f: impl FnMut() -> f64) -> BenchResult {
    let r = bench(name, units_per_iter, f);
    println!("{r}");
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("FP8TRAIN_BENCH_FAST", "1");
        let r = bench("noop-ish", Some(100.0), || {
            (0..100).map(|i| i as f64).sum::<f64>()
        });
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_rendering() {
        let r = BenchResult {
            name: "gemm/\"odd\"/name".into(),
            iters: 10,
            mean: Duration::from_nanos(1500),
            p50: Duration::from_nanos(1400),
            p99: Duration::from_nanos(2000),
            units_per_iter: Some(100.0),
        };
        let j = r.to_json();
        assert!(j.contains("\\\"odd\\\""), "{j}");
        assert!(j.contains("\"mean_ns\":1500"), "{j}");
        assert!(j.starts_with('{') && j.ends_with('}'));
        let none = BenchResult { units_per_iter: None, ..r };
        assert!(none.to_json().contains("\"units_per_sec\":null"));
    }

    #[test]
    fn json_escape_controls() {
        // Shared escaper (benchcmp::escape): common controls use the short
        // escapes, everything else below 0x20 the \uXXXX form.
        assert_eq!(super::json_escape("a\"b\\c\nd\u{1}"), "a\\\"b\\\\c\\nd\\u0001");
    }

    #[test]
    fn human_rates() {
        assert_eq!(super::human_rate(2.5e9), "2.50G/s");
        assert_eq!(super::human_rate(5.0e6), "5.00M/s");
        assert_eq!(super::human_rate(1.5e3), "1.50K/s");
        assert_eq!(super::human_rate(10.0), "10.00/s");
    }
}
