//! Minimal property-based testing harness.
//!
//! The offline build has no `proptest`/`quickcheck`, so this module
//! provides the small subset the test-suite needs: seeded generators,
//! a `forall` driver with deterministic replay seeds, and float-comparison
//! helpers mirroring numpy's `allclose`.

use crate::numerics::dot::{dot, dot_f32, GemmPrecision};
use crate::numerics::format::FloatFormat;
use crate::numerics::gemm::transpose;
use crate::numerics::rng::{SplitMix64, Xoshiro256};
use crate::numerics::rounding::RoundMode;

/// Number of cases per property (overridable via `FP8TRAIN_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("FP8TRAIN_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256)
}

/// A seeded generator context handed to property closures.
pub struct Gen {
    pub rng: Xoshiro256,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Xoshiro256::seed_from_u64(seed),
        }
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    /// "Interesting" f32: mixes uniform magnitudes across many binades,
    /// exact powers of two, zeros and boundary values — the distribution
    /// quantizer bugs hide in.
    pub fn f32_any(&mut self) -> f32 {
        match self.rng.below(16) {
            0 => 0.0,
            1 => -0.0,
            2 => self.rng.uniform(-1.0, 1.0),
            3 => 2f32.powi(self.rng.below(60) as i32 - 30),
            4 => -(2f32.powi(self.rng.below(60) as i32 - 30)),
            5 => f32::MIN_POSITIVE * self.rng.uniform(0.0, 4.0),
            6 => 57344.0 * self.rng.uniform(0.9, 1.1), // FP8 max boundary
            _ => {
                let e = self.rng.below(80) as i32 - 40;
                self.rng.uniform(-1.0, 1.0) * 2f32.powi(e)
            }
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo) as u32) as usize
    }

    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_any(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_any()).collect()
    }
}

/// Run `prop` over `default_cases()` seeded generator contexts; panics with
/// the seed of the first failing case so it can be replayed exactly.
pub fn forall<F: Fn(&mut Gen) -> Result<(), String>>(name: &str, prop: F) {
    let cases = default_cases();
    let base = 0x5EED_F00D_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed (replay seed {seed:#x}, case {case}): {msg}");
        }
    }
}

/// Seeded random `r×s` matrix quantized onto the FP8 grid — the standard
/// GEMM-test operand (shared by unit tests, the equivalence suite, and the
/// bench CLI so all of them exercise identical data).
pub fn fp8_matrix(r: usize, s: usize, seed: u64, lo: f32, hi: f32) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut v: Vec<f32> = (0..r * s).map(|_| rng.uniform(lo, hi)).collect();
    FloatFormat::FP8.quantize_slice(&mut v, RoundMode::NearestEven);
    v
}

/// The **pre-refactor GEMM kernels**, one dot product per output element
/// with one RNG stream per row: the normative bit-equivalence reference
/// for the blocked/panel execution layer. The per-row stream derivation
/// here *is* the determinism contract the production kernels must match.
pub fn reference_gemm(
    prec: &GemmPrecision,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
) -> Vec<f32> {
    let bt = transpose(b, k, n);
    let mut c = vec![0f32; m * n];
    if k == 0 {
        return c;
    }
    for i in 0..m {
        let mut sm = SplitMix64::new(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Xoshiro256::seed_from_u64(sm.next_u64());
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let col = &bt[j * k..(j + 1) * k];
            c[i * n + j] = if prec.is_fp32() {
                dot_f32(arow, col)
            } else {
                dot(prec, arow, col, &mut rng)
            };
        }
    }
    c
}

/// Relative-or-absolute closeness check mirroring numpy's `allclose`.
pub fn allclose(a: f32, b: f32, rtol: f64, atol: f64) -> bool {
    if a.is_nan() && b.is_nan() {
        return true;
    }
    ((a as f64) - (b as f64)).abs() <= atol + rtol * (b as f64).abs()
}

/// Assert two slices are elementwise close; reports the first offender.
pub fn assert_slices_close(a: &[f32], b: &[f32], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            allclose(x, y, rtol, atol),
            "mismatch at {i}: {x} vs {y} (rtol={rtol}, atol={atol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("addition commutes", |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a} + {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failures() {
        forall("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn allclose_semantics() {
        assert!(allclose(1.0, 1.0 + 1e-7, 1e-5, 0.0));
        assert!(!allclose(1.0, 1.1, 1e-5, 0.0));
        assert!(allclose(f32::NAN, f32::NAN, 0.0, 0.0));
        assert!(allclose(0.0, 1e-9, 0.0, 1e-8));
    }
}
