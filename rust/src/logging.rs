//! Logging + metrics sinks.
//!
//! A tiny in-tree leveled stderr logger (the offline env has no `log`/
//! `env_logger` — this workspace builds with zero external crates) plus
//! the CSV metrics writer used by the trainer and every experiment harness
//! to emit the convergence curves behind Figs. 1/4/5.
//!
//! Use via the crate-root macros: `crate::log_info!("…")` etc.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Highest level that prints; default `Info` even before [`init`].
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(Level::Info as usize);

/// Resolve an `FP8TRAIN_LOG` value to a level, plus a warning for values
/// that name no level (a misspelled `FP8TRAIN_LOG=dbug` silently running
/// at info would hide exactly the diagnostics the user asked for). Unset
/// and `info` both map cleanly to the default.
fn parse_level(var: Option<&str>) -> (Level, Option<String>) {
    match var {
        Some("error") => (Level::Error, None),
        Some("warn") => (Level::Warn, None),
        Some("info") | None => (Level::Info, None),
        Some("debug") => (Level::Debug, None),
        Some("trace") => (Level::Trace, None),
        Some(other) => (
            Level::Info,
            Some(format!(
                "[FP8TRAIN_LOG]: unknown value {other:?} (expected one of error, warn, info, \
                 debug, trace); using info"
            )),
        ),
    }
}

/// Set the level from `FP8TRAIN_LOG` (error|warn|info|debug|trace, default
/// info). Idempotent; an unrecognized value warns once and keeps the
/// default rather than failing startup.
pub fn init() {
    let (level, warning) = parse_level(std::env::var("FP8TRAIN_LOG").ok().as_deref());
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
    if let Some(w) = warning {
        static WARNED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);
        if !WARNED.swap(true, Ordering::Relaxed) {
            log(Level::Warn, "logging", format_args!("{w}"));
        }
    }
}

pub fn enabled(level: Level) -> bool {
    level as usize <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record (used by the `log_*!` macros; prefer those).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!(
            "[{:<5} {}] {}",
            level.label(),
            target.split("::").last().unwrap_or(""),
            args
        );
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::logging::log($crate::logging::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

/// Append-only CSV writer with a fixed header, used for metric curves.
/// Thread-safe (the coordinator's workers share one sink).
pub struct CsvSink {
    inner: Mutex<BufWriter<File>>,
    pub columns: Vec<String>,
}

impl CsvSink {
    /// Create/truncate `path` and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, columns: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", columns.join(","))?;
        Ok(Self {
            inner: Mutex::new(w),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// Write one row; panics if the column count mismatches the header
    /// (catching that early beats silently misaligned CSVs).
    pub fn row(&self, values: &[f64]) {
        assert_eq!(
            values.len(),
            self.columns.len(),
            "CSV row arity mismatch"
        );
        let mut w = self.inner.lock().unwrap();
        // Non-finite values serialize as the empty cell — `NaN`/`inf` are
        // not valid CSV numbers and break downstream numeric parsers; an
        // empty cell is the canonical "no value" every reader understands.
        let line = values
            .iter()
            .map(|v| {
                if v.is_finite() {
                    format!("{v}")
                } else {
                    String::new()
                }
            })
            .collect::<Vec<_>>()
            .join(",");
        writeln!(w, "{line}").expect("csv write");
    }

    pub fn flush(&self) {
        self.inner.lock().unwrap().flush().expect("csv flush");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_order_and_default_filter() {
        assert!(Level::Error < Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Trace)); // default level is Info
    }

    #[test]
    fn parse_level_accepts_the_documented_set() {
        for (s, want) in [
            ("error", Level::Error),
            ("warn", Level::Warn),
            ("info", Level::Info),
            ("debug", Level::Debug),
            ("trace", Level::Trace),
        ] {
            let (level, warning) = parse_level(Some(s));
            assert_eq!(level, want, "FP8TRAIN_LOG={s}");
            assert!(warning.is_none(), "FP8TRAIN_LOG={s} should not warn");
        }
        let (level, warning) = parse_level(None);
        assert_eq!(level, Level::Info);
        assert!(warning.is_none());
    }

    #[test]
    fn parse_level_warns_once_style_on_unknown_value() {
        let (level, warning) = parse_level(Some("dbug"));
        assert_eq!(level, Level::Info, "unknown value keeps the default");
        let msg = warning.expect("unknown value must produce a warning");
        assert!(msg.contains("[FP8TRAIN_LOG]"), "{msg}");
        assert!(msg.contains("unknown value \"dbug\""), "{msg}");
        assert!(
            msg.contains("error, warn, info, debug, trace"),
            "warning must name the accepted set: {msg}"
        );
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("fp8train_test_csv");
        let path = dir.join("m.csv");
        let sink = CsvSink::create(&path, &["step", "loss"]).unwrap();
        sink.row(&[1.0, 0.5]);
        sink.row(&[2.0, 0.25]);
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,0.5\n2,0.25\n");
    }

    #[test]
    fn csv_non_finite_values_become_empty_cells() {
        let dir = std::env::temp_dir().join("fp8train_test_csv_nonfinite");
        let path = dir.join("m.csv");
        let sink = CsvSink::create(&path, &["step", "loss", "err"]).unwrap();
        sink.row(&[1.0, f64::NAN, 0.5]);
        sink.row(&[2.0, f64::INFINITY, f64::NEG_INFINITY]);
        sink.row(&[3.0, 0.25, 0.125]);
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss,err\n1,,0.5\n2,,\n3,0.25,0.125\n");
        assert!(!text.contains("NaN") && !text.contains("inf"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn csv_arity_checked() {
        let dir = std::env::temp_dir().join("fp8train_test_csv2");
        let sink = CsvSink::create(dir.join("m.csv"), &["a", "b"]).unwrap();
        sink.row(&[1.0]);
    }
}
