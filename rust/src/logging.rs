//! Logging + metrics sinks.
//!
//! A tiny `log`-crate backend (the offline env has no `env_logger`) plus
//! the CSV metrics writer used by the trainer and every experiment harness
//! to emit the convergence curves behind Figs. 1/4/5.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Mutex;

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:<5} {}] {}",
                record.level(),
                record.target().split("::").last().unwrap_or(""),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the stderr logger. Level from `FP8TRAIN_LOG` (error..trace),
/// default `info`. Idempotent.
pub fn init() {
    let level = match std::env::var("FP8TRAIN_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    // set_logger errors if called twice — fine, ignore.
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

/// Append-only CSV writer with a fixed header, used for metric curves.
/// Thread-safe (the coordinator's workers share one sink).
pub struct CsvSink {
    inner: Mutex<BufWriter<File>>,
    pub columns: Vec<String>,
}

impl CsvSink {
    /// Create/truncate `path` and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, columns: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", columns.join(","))?;
        Ok(Self {
            inner: Mutex::new(w),
            columns: columns.iter().map(|s| s.to_string()).collect(),
        })
    }

    /// Write one row; panics if the column count mismatches the header
    /// (catching that early beats silently misaligned CSVs).
    pub fn row(&self, values: &[f64]) {
        assert_eq!(
            values.len(),
            self.columns.len(),
            "CSV row arity mismatch"
        );
        let mut w = self.inner.lock().unwrap();
        let line = values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(w, "{line}").expect("csv write");
    }

    pub fn flush(&self) {
        self.inner.lock().unwrap().flush().expect("csv flush");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("fp8train_test_csv");
        let path = dir.join("m.csv");
        let sink = CsvSink::create(&path, &["step", "loss"]).unwrap();
        sink.row(&[1.0, 0.5]);
        sink.row(&[2.0, 0.25]);
        sink.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,0.5\n2,0.25\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn csv_arity_checked() {
        let dir = std::env::temp_dir().join("fp8train_test_csv2");
        let sink = CsvSink::create(dir.join("m.csv"), &["a", "b"]).unwrap();
        sink.row(&[1.0]);
    }
}
