//! The L3 coordinator: the engine abstraction the trainer, experiments and
//! examples drive.
//!
//! The paper's contribution is a numeric format + accumulation scheme, so
//! (per DESIGN.md §2) L3 is a thin driver around two interchangeable
//! engines:
//!
//! - [`NativeEngine`] — the Rust emulation engine (`nn/` + `numerics/`),
//!   used by every paper experiment;
//! - [`crate::runtime::PjrtEngine`] — the deployable path: the same
//!   quantized train-step AOT-compiled from JAX/Pallas to an HLO artifact
//!   and executed through PJRT with device-resident state.
//!
//! Both implement [`Engine`]; `train::Trainer` is engine-agnostic.

pub mod native;

pub use native::NativeEngine;

use crate::data::Batch;
use crate::state::{StateError, StateMap};

/// One training/eval step provider.
pub trait Engine {
    fn name(&self) -> String;

    /// Run one optimization step on `batch` at learning rate `lr`;
    /// returns the (unscaled) training loss.
    fn train_step(&mut self, batch: &Batch, lr: f32, step: u64) -> f64;

    /// Evaluate `batch`: returns (summed loss, #correct).
    fn eval(&mut self, batch: &Batch) -> (f64, usize);

    /// Learnable parameter count (Table 1 model sizes).
    fn num_params(&mut self) -> usize;

    /// Serialize everything a bit-exact resume needs: `engine.name` (the
    /// compatibility tag), model parameters + extra layer state under
    /// `model.*`, optimizer state under `optim.*`.
    fn save_state(&mut self, out: &mut StateMap);

    /// Strict restore counterpart of [`save_state`](Self::save_state):
    /// rejects checkpoints written by a different (model, policy, engine)
    /// combination rather than silently diverging.
    fn load_state(&mut self, src: &StateMap) -> Result<(), StateError>;
}

/// Evaluate an engine over a full test set; returns (mean loss, error %).
pub fn evaluate(engine: &mut dyn Engine, batches: &[Batch]) -> (f64, f64) {
    let mut loss = 0f64;
    let mut correct = 0usize;
    let mut total = 0usize;
    for b in batches {
        let (l, c) = engine.eval(b);
        loss += l * b.len() as f64;
        correct += c;
        total += b.len();
    }
    if total == 0 {
        return (0.0, 100.0);
    }
    (
        loss / total as f64,
        100.0 * (1.0 - correct as f64 / total as f64),
    )
}
