//! The native emulation engine: model + optimizer + precision policy.

use super::Engine;
use crate::data::Batch;
use crate::nn::{softmax_xent, Layer, ModelSpec, PrecisionPolicy, QuantCtx, Sequential};
use crate::optim::{Optimizer, Sgd};
use crate::program::StepProgram;
use crate::state::{StateDict, StateError, StateMap};

/// Batch size step programs are planned for (`docs/step-program.md`). The
/// plan models shapes and operand lifetimes; the executor itself is
/// batch-size-agnostic, so this only has to be representative.
const PROGRAM_PLAN_BATCH: usize = 32;

pub struct NativeEngine {
    pub model: Sequential,
    pub policy: PrecisionPolicy,
    pub opt: Box<dyn Optimizer>,
    name: String,
    /// Compiled step program; when present, `train_step`/`eval`/
    /// `predict_logits` execute it instead of interpreting the layer list.
    /// Bit-identical either way (`rust/tests/program_equivalence.rs`), so
    /// the engine name — and therefore checkpoint compatibility — does not
    /// depend on which path runs.
    program: Option<StepProgram>,
}

impl NativeEngine {
    /// Standard construction: SGD(momentum 0.9, weight decay 1e-4), master
    /// weights quantized into the policy's update format. The engine name
    /// embeds `spec.id()` — the preset id for presets (so historical
    /// checkpoints keep their engine tag) or the canonical DSL string.
    pub fn new(spec: &ModelSpec, policy: PrecisionPolicy, seed: u64) -> Self {
        let opt = Box::new(Sgd::new(0.9, 1e-4, seed ^ 0x0117));
        Self::with_optimizer(spec, policy, opt, seed)
    }

    pub fn with_optimizer(
        spec: &ModelSpec,
        policy: PrecisionPolicy,
        mut opt: Box<dyn Optimizer>,
        seed: u64,
    ) -> Self {
        let mut model = spec.build(seed);
        opt.prepare(&mut model, &policy);
        // Opt-in program execution for paths that construct engines
        // internally (serve checkpoint reload, sweeps): the CLI's
        // `--engine-program` flag calls `with_program` explicitly.
        let program = std::env::var("FP8TRAIN_ENGINE_PROGRAM")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false)
            .then(|| StepProgram::lower(spec, &policy, PROGRAM_PLAN_BATCH));
        Self {
            name: format!("native:{}:{}", spec.id(), policy.name),
            model,
            policy,
            opt,
            program,
        }
    }

    /// Compile and attach a step program: subsequent train/eval/predict
    /// calls execute the program instead of the layer-list interpreter.
    pub fn with_program(mut self, spec: &ModelSpec) -> Self {
        self.program = Some(StepProgram::lower(spec, &self.policy, PROGRAM_PLAN_BATCH));
        self
    }

    /// The attached step program, when the engine runs in program mode.
    pub fn program(&self) -> Option<&StepProgram> {
        self.program.as_ref()
    }

    /// Forward + loss without a weight update (used by experiments that
    /// inspect intermediate tensors).
    pub fn forward_loss(&mut self, batch: &Batch, step: u64, train: bool) -> f64 {
        let ctx = QuantCtx::new(&self.policy, step, train);
        let logits = self.model.forward(batch.x.clone(), &ctx);
        softmax_xent(&logits, &batch.labels, self.policy.softmax_input_fmt, 1.0).loss
    }

    /// Model-only restore (weights + BatchNorm statistics): enough for
    /// inference, skipping optimizer state — `fp8train eval --checkpoint`
    /// uses this, so a checkpoint serves regardless of which optimizer the
    /// serving engine was constructed with. Weights land directly in the
    /// `[out, in]` layout the packed-operand GEMM path consumes, so the
    /// eval loop runs transpose-free from the first batch.
    pub fn load_model_state(&mut self, src: &StateMap) -> Result<(), StateError> {
        self.model.load_state("model", src)
    }

    /// Raw logits under the eval quantization context (step 0, train
    /// false — exactly what [`Engine::eval`] uses). This is the serving
    /// entry (`fp8train serve`): every output row depends only on its own
    /// input row and the weights (eval BatchNorm reads running statistics,
    /// GEMM output elements have a fixed summation order), so a
    /// micro-batched forward is bit-identical to N single-row forwards —
    /// the determinism contract `rust/tests/serve_equivalence.rs` enforces.
    pub fn predict_logits(&mut self, x: crate::tensor::Tensor) -> crate::tensor::Tensor {
        if let Some(prog) = self.program.as_ref() {
            return prog.predict_logits(&mut self.model, &self.policy, x);
        }
        let ctx = QuantCtx::new(&self.policy, 0, false);
        self.model.forward(x, &ctx)
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn train_step(&mut self, batch: &Batch, lr: f32, step: u64) -> f64 {
        if let Some(prog) = self.program.as_ref() {
            return prog.train_step(
                &mut self.model,
                self.opt.as_mut(),
                &self.policy,
                batch,
                lr,
                step,
            );
        }
        let ctx = QuantCtx::new(&self.policy, step, true);
        let logits = self.model.forward(batch.x.clone(), &ctx);
        let out = softmax_xent(
            &logits,
            &batch.labels,
            self.policy.softmax_input_fmt,
            self.policy.loss_scale,
        );
        self.model.backward(out.dlogits, &ctx);
        crate::perf::timed(crate::perf::Phase::Update, || {
            self.opt.step(&mut self.model, &self.policy, lr, step)
        });
        out.loss
    }

    fn eval(&mut self, batch: &Batch) -> (f64, usize) {
        if let Some(prog) = self.program.as_ref() {
            return prog.eval(&mut self.model, &self.policy, batch);
        }
        let ctx = QuantCtx::new(&self.policy, 0, false);
        let logits = self.model.forward(batch.x.clone(), &ctx);
        let out = softmax_xent(&logits, &batch.labels, self.policy.softmax_input_fmt, 1.0);
        (out.loss, out.correct)
    }

    fn num_params(&mut self) -> usize {
        self.model.num_params()
    }

    fn save_state(&mut self, out: &mut StateMap) {
        out.put_str("engine.name", &self.name);
        self.model.save_state("model", out);
        self.opt.save_state(out);
    }

    fn load_state(&mut self, src: &StateMap) -> Result<(), StateError> {
        let name = src.get_str("engine.name")?;
        if name != self.name {
            return Err(StateError::Incompatible(format!(
                "checkpoint was written by engine {name:?}, this engine is {:?}",
                self.name
            )));
        }
        self.model.load_state("model", src)?;
        self.opt.load_state(src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluate;
    use crate::data::SyntheticDataset;

    #[test]
    fn loss_decreases_on_tiny_problem() {
        let spec = ModelSpec::cifar_cnn();
        let ds = SyntheticDataset::for_model(&spec, 1).with_sizes(64, 32);
        let mut e = NativeEngine::new(&spec, PrecisionPolicy::fp32(), 1);
        let first = e.train_step(&ds.train_batch(0, 16), 0.02, 0);
        let mut last = first;
        for step in 1..30 {
            last = e.train_step(&ds.train_batch(step % 4, 16), 0.02, step as u64);
        }
        assert!(
            last < first * 0.8,
            "loss did not decrease: {first} → {last}"
        );
    }

    #[test]
    fn evaluate_reports_error_percent() {
        let spec = ModelSpec::bn50_dnn();
        let ds = SyntheticDataset::for_model(&spec, 2).with_sizes(64, 48);
        let mut e = NativeEngine::new(&spec, PrecisionPolicy::fp32(), 2);
        let (loss, err) = evaluate(&mut e, &ds.test_batches(16));
        assert!(loss > 0.0);
        assert!((0.0..=100.0).contains(&err));
    }

    #[test]
    fn engine_state_round_trip_is_bit_exact_and_strict() {
        let spec = ModelSpec::bn50_dnn();
        let ds = SyntheticDataset::for_model(&spec, 5).with_sizes(32, 16);
        let mut e = NativeEngine::new(&spec, PrecisionPolicy::fp8_paper(), 5);
        for step in 0..3 {
            e.train_step(&ds.train_batch(step % 2, 8), 0.05, step as u64);
        }
        let mut map = StateMap::new();
        e.save_state(&mut map);
        // A fresh engine with a different seed converges to identical state.
        let mut f = NativeEngine::new(&spec, PrecisionPolicy::fp8_paper(), 99);
        f.load_state(&map).unwrap();
        let mut map2 = StateMap::new();
        f.save_state(&mut map2);
        assert_eq!(map, map2, "restored state must be bit-identical");
        // Continuing both engines produces bit-identical losses.
        let b = ds.train_batch(1, 8);
        let la = e.train_step(&b, 0.05, 3);
        let lb = f.train_step(&b, 0.05, 3);
        assert_eq!(la.to_bits(), lb.to_bits());
        // Wrong (model, policy) pairings are rejected loudly.
        let mut wrong = NativeEngine::new(&spec, PrecisionPolicy::fp32(), 5);
        assert!(wrong.load_state(&map).is_err());
    }

    #[test]
    fn wrong_engine_tag_error_names_both_engines() {
        // A supervisor deciding "restart this cell from scratch" gets its
        // signal from this message — it must identify both sides.
        let spec = ModelSpec::bn50_dnn();
        let mut e = NativeEngine::new(&spec, PrecisionPolicy::fp8_paper(), 1);
        let mut map = StateMap::new();
        e.save_state(&mut map);
        let mut wrong = NativeEngine::new(&spec, PrecisionPolicy::fp32(), 1);
        let err = wrong.load_state(&map).unwrap_err();
        assert!(matches!(err, StateError::Incompatible(_)), "{err}");
        let msg = err.to_string();
        assert!(
            msg.contains("fp8_paper") && msg.contains("fp32"),
            "{msg}"
        );
    }

    #[test]
    fn program_engine_matches_interpreter_bit_for_bit() {
        let spec = ModelSpec::bn50_dnn();
        let ds = SyntheticDataset::for_model(&spec, 11).with_sizes(32, 16);
        let mut interp = NativeEngine::new(&spec, PrecisionPolicy::fp8_paper(), 11);
        let mut prog = NativeEngine::new(&spec, PrecisionPolicy::fp8_paper(), 11)
            .with_program(&spec);
        assert!(prog.program().is_some());
        // Same engine tag either way: checkpoints interoperate.
        assert_eq!(interp.name(), prog.name());
        for step in 0..4u64 {
            let b = ds.train_batch((step % 2) as usize, 8);
            let la = interp.train_step(&b, 0.05, step);
            let lb = prog.train_step(&b, 0.05, step);
            assert_eq!(la.to_bits(), lb.to_bits(), "loss diverged at step {step}");
        }
        let b = ds.test_batches(8);
        let (l1, c1) = interp.eval(&b[0]);
        let (l2, c2) = prog.eval(&b[0]);
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(c1, c2);
        let mut m1 = StateMap::new();
        let mut m2 = StateMap::new();
        interp.save_state(&mut m1);
        prog.save_state(&mut m2);
        assert_eq!(m1, m2, "checkpoint state must be bit-identical");
    }

    #[test]
    fn fp8_engine_trains() {
        let spec = ModelSpec::bn50_dnn();
        let ds = SyntheticDataset::for_model(&spec, 3).with_sizes(64, 32);
        let mut e = NativeEngine::new(&spec, PrecisionPolicy::fp8_paper(), 3);
        let first = e.train_step(&ds.train_batch(0, 16), 0.05, 0);
        let mut last = first;
        for step in 1..40 {
            last = e.train_step(&ds.train_batch(step % 4, 16), 0.05, step as u64);
        }
        assert!(last < first, "fp8 loss did not move: {first} → {last}");
    }
}
