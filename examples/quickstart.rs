//! Quickstart: the library in ~60 lines.
//!
//! 1. Quantize values into the paper's FP8 (1,5,2) / FP16 (1,6,9) formats.
//! 2. Watch swamping kill a long FP16 accumulation — and chunking fix it.
//! 3. Train a small model under the full FP8 policy and compare with FP32.
//!
//! Run: `cargo run --release --example quickstart`

use fp8train::coordinator::NativeEngine;
use fp8train::data::SyntheticDataset;
use fp8train::nn::{ModelSpec, PrecisionPolicy};
use fp8train::numerics::accumulate::{acc_chunked, acc_f64, acc_sequential};
use fp8train::numerics::{FloatFormat, RoundMode, Xoshiro256};
use fp8train::train::{train, TrainConfig};

fn main() {
    // --- 1. the formats -------------------------------------------------
    let fp8 = FloatFormat::FP8;
    let fp16 = FloatFormat::FP16;
    println!("FP8  (1,5,2): max {}, min subnormal {}", fp8.max_normal(), fp8.min_subnormal());
    println!("FP16 (1,6,9): max {:e}, swamping ratio 2^{}", fp16.max_normal(), fp16.mbits + 1);
    println!("quantize(1.1) -> FP8 = {}", fp8.quantize(1.1, RoundMode::NearestEven));

    // --- 2. swamping vs chunking (the paper's Fig. 3b in four lines) ----
    let mut rng = Xoshiro256::seed_from_u64(1);
    let xs: Vec<f32> = (0..65536).map(|_| rng.uniform(0.0, 2.0)).collect();
    let exact = acc_f64(&xs);
    let seq = acc_sequential(fp16, RoundMode::NearestEven, &xs, &mut rng);
    let chunked = acc_chunked(fp16, RoundMode::NearestEven, 64, &xs, &mut rng);
    println!("\nsum of 65536 uniform values: exact {exact:.0}");
    println!("  FP16 sequential (swamped): {seq:.0}");
    println!("  FP16 chunked CL=64:        {chunked:.0}");

    // --- 3. FP8 training vs FP32 ----------------------------------------
    let spec = ModelSpec::cifar_cnn();
    let ds = SyntheticDataset::for_model(&spec, 7).with_sizes(512, 256);
    for policy in [PrecisionPolicy::fp32(), PrecisionPolicy::fp8_paper()] {
        let name = policy.name.clone();
        let mut engine = NativeEngine::new(&spec, policy, 7);
        let r = train(&mut engine, &ds, &TrainConfig::quick(150));
        println!(
            "{name:>10}: final train loss {:.3}, test error {:.1}%",
            r.final_train_loss, r.final_test_err
        );
    }
    println!("\n(fp8_paper = FP8 GEMMs + FP16 chunked accumulation + FP16-SR updates)");
}
