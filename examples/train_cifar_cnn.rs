//! END-TO-END DRIVER (the repository's required e2e validation): train
//! CIFAR10-CNN through the **AOT-compiled JAX/Pallas train step executed
//! via PJRT from the Rust coordinator** — Python never runs here — and
//! cross-check against the native Rust emulation engine on the same data.
//!
//! Prerequisite: `make artifacts`.
//! Run: `cargo run --release --example train_cifar_cnn [steps] [policy]`
//! (default 200 steps, policy fp8; EXPERIMENTS.md §E2E records a run).

use fp8train::coordinator::{Engine, NativeEngine};
use fp8train::data::SyntheticDataset;
use fp8train::nn::{ModelSpec, PrecisionPolicy};
use fp8train::runtime::{PjrtEngine, Runtime};
use fp8train::train::{train, LrSchedule, TrainConfig};

fn main() -> fp8train::error::Result<()> {
    fp8train::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let which = args.get(2).map(String::as_str).unwrap_or("fp8").to_string();
    let spec = ModelSpec::cifar_cnn();
    let seed = 42;

    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let mut pjrt = PjrtEngine::load(&rt, &format!("cifar_cnn_{which}"), seed)?;
    let batch = pjrt.batch_size();
    let ds = SyntheticDataset::for_model(&spec, seed);
    let cfg = TrainConfig {
        batch_size: batch,
        steps,
        schedule: LrSchedule::step_decay(0.02, steps),
        eval_every: (steps / 10).max(1),
        csv: Some(format!("results/e2e_pjrt_{which}.csv")),
        verbose: true,
        ..TrainConfig::quick(steps)
    };
    std::fs::create_dir_all("results").ok();

    println!(
        "\n=== PJRT engine ({}), {} params, batch {batch}, {steps} steps ===",
        pjrt.name(),
        pjrt.num_params()
    );
    let t0 = std::time::Instant::now();
    let r_pjrt = train(&mut pjrt, &ds, &cfg);
    let pjrt_time = t0.elapsed();

    // The same workload on the native Rust emulation engine.
    let policy = match which.as_str() {
        "fp32" => PrecisionPolicy::fp32(),
        _ => PrecisionPolicy::fp8_paper(),
    };
    let mut native = NativeEngine::new(&spec, policy, seed);
    let mut cfg_native = cfg.clone();
    cfg_native.csv = Some(format!("results/e2e_native_{which}.csv"));
    println!("\n=== Native engine ({}) ===", native.name());
    let t0 = std::time::Instant::now();
    let r_native = train(&mut native, &ds, &cfg_native);
    let native_time = t0.elapsed();

    println!("\n=== E2E summary ({which}, {steps} steps) ===");
    println!(
        "PJRT  : final loss {:.4}, test err {:>6.2}%, {:>8.1?} total ({:.0} ms/step)",
        r_pjrt.final_train_loss,
        r_pjrt.final_test_err,
        pjrt_time,
        pjrt_time.as_millis() as f64 / steps as f64
    );
    println!(
        "native: final loss {:.4}, test err {:>6.2}%, {:>8.1?} total ({:.0} ms/step)",
        r_native.final_train_loss,
        r_native.final_test_err,
        native_time,
        native_time.as_millis() as f64 / steps as f64
    );
    let d = (r_pjrt.final_test_err - r_native.final_test_err).abs();
    println!(
        "agreement: |Δ test err| = {d:.2}% — two independent implementations of the \
         same FP8 scheme (loss curves in results/e2e_*.csv)"
    );
    Ok(())
}
