//! Format explorer: re-run the §2.2 design study — why (1,5,2)/(1,6,9)?
//!
//! For a menu of candidate (ebits, mbits) splits, measures representation
//! SQNR and saturation/underflow rates on tensors with DNN-like
//! distributions (weights ~ N(0, 0.05), activations ~ half-normal,
//! loss-scaled errors ~ N(0, 1e-3·scale)), plus the dynamic-range needs of
//! the update path. Prints the trade-off table that motivates the paper's
//! choice: FP8 needs the 5-bit exponent for error dynamic range; FP16
//! accumulation needs the 6-bit exponent to cover weight-update magnitudes.
//!
//! Run: `cargo run --release --example format_explorer`

use fp8train::numerics::stats::quant_report;
use fp8train::numerics::{FloatFormat, Xoshiro256};

fn tensor(kind: &str, n: usize, rng: &mut Xoshiro256) -> Vec<f32> {
    (0..n)
        .map(|_| match kind {
            "weights" => rng.normal() * 0.05,
            "acts" => (rng.normal() * 0.5).abs() + 0.01,
            // loss-scaled backprop errors: small magnitudes, long tail
            "errors" => rng.normal() * 1e-3 * 1000.0 * (1.0 + rng.normal().abs() * 3.0),
            _ => unreachable!(),
        })
        .collect()
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(2);
    let candidates = [
        FloatFormat { ebits: 2, mbits: 5 },
        FloatFormat { ebits: 3, mbits: 4 },
        FloatFormat { ebits: 4, mbits: 3 },
        FloatFormat { ebits: 5, mbits: 2 }, // the paper's FP8
        FloatFormat { ebits: 6, mbits: 1 },
    ];
    for kind in ["weights", "acts", "errors"] {
        let xs = tensor(kind, 100_000, &mut rng);
        println!("\n=== 8-bit candidates on {kind} ===");
        println!(
            "{:<10} {:>10} {:>12} {:>12}",
            "format", "SQNR_dB", "saturated_%", "flushed_%"
        );
        for fmt in candidates {
            let r = quant_report(fmt, &xs);
            println!(
                "{:<10} {:>10.2} {:>12.4} {:>12.4}",
                fmt.name(),
                r.sqnr_db,
                100.0 * r.overflow_frac,
                100.0 * r.underflow_frac
            );
        }
    }

    // 16-bit accumulation/update candidates: the update path needs range
    // for w ± lr·v with v spanning many octaves.
    let sixteens = [
        FloatFormat { ebits: 5, mbits: 10 }, // IEEE half
        FloatFormat { ebits: 6, mbits: 9 },  // the paper's FP16
        FloatFormat { ebits: 8, mbits: 7 },  // bfloat16
    ];
    let mut upd: Vec<f32> = Vec::new();
    for _ in 0..100_000 {
        let w = rng.normal() * 0.05;
        let v = rng.normal() * 10f32.powi(-(rng.below(6) as i32)); // 1e0..1e-5
        upd.push(w - 0.1 * v);
        upd.push(v);
    }
    println!("\n=== 16-bit candidates on the weight-update path ===");
    println!(
        "{:<12} {:>10} {:>12} {:>12}",
        "format", "SQNR_dB", "saturated_%", "flushed_%"
    );
    for fmt in sixteens {
        let r = quant_report(fmt, &upd);
        println!(
            "{:<12} {:>10.2} {:>12.4} {:>12.4}",
            fmt.name(),
            r.sqnr_db,
            100.0 * r.overflow_frac,
            100.0 * r.underflow_frac
        );
    }
    println!("\n(the paper's choices balance SQNR against dynamic range: (1,5,2) is the\n only 8-bit split with zero saturation on loss-scaled errors AND usable\n mantissa; (1,6,9) trades one IEEE-half mantissa bit for 2x the range)");
}
