"""L2 model tests: the quantized CIFAR-CNN train step that aot.py lowers."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.model import FP8_PAPER, FP32_BASELINE, make_fwd, make_train_step
from compile.quant import FP16


def make_batch(seed, batch=8):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(kx, (batch, *model.INPUT_SHAPE), jnp.float32, 0.0, 2.0)
    labels = jax.random.randint(ky, (batch,), 0, model.CLASSES)
    return x, jax.nn.one_hot(labels, model.CLASSES, dtype=jnp.float32)


def test_param_specs_match_manifest_convention():
    specs = model.param_specs()
    assert [n for n, _ in specs] == [
        "conv1.w", "conv1.b", "conv2.w", "conv2.b",
        "conv3.w", "conv3.b", "fc.w", "fc.b",
    ]
    assert specs[0][1] == (16, 75)
    assert specs[-2][1] == (10, 512)


def test_forward_shapes_both_policies():
    params = model.init_params(0)
    x, _ = make_batch(0)
    for policy in (FP32_BASELINE, FP8_PAPER):
        (logits,) = make_fwd(policy)(*params, x)
        assert logits.shape == (8, model.CLASSES)
        assert np.isfinite(np.asarray(logits)).all()


def test_custom_vjp_matches_autodiff_under_fp32():
    # With the FP32 policy the custom VJP must equal plain autodiff.
    params = model.init_params(1)
    x, y = make_batch(1)

    def loss_plain(params):
        qg = lambda a, w: jnp.dot(a, w.T, preferred_element_type=jnp.float32)
        it = iter(params)
        h = x
        for name, cfg in model.LAYERS[:3]:
            w, b = next(it), next(it)
            rows, n = model._patches(h, cfg["k"])
            oh = h.shape[2]
            h = (qg(rows, w) + b).reshape(n, oh, oh, cfg["out_c"]).transpose(0, 3, 1, 2)
            h = model._maxpool2(jnp.maximum(h, 0.0))
        w, b = next(it), next(it)
        logits = qg(h.reshape(h.shape[0], -1), w) + b
        logp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.sum(y * logp, -1))

    g_plain = jax.grad(loss_plain)(params)
    g_policy = jax.grad(lambda p: model.loss_fn(FP32_BASELINE, p, x, y))(params)
    for a, b in zip(g_plain, g_policy):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_fp32_train_step_decreases_loss():
    step_fn = jax.jit(make_train_step(FP32_BASELINE))
    params = model.init_params(2)
    moms = [jnp.zeros_like(p) for p in params]
    x, y = make_batch(2)
    state = params + moms
    losses = []
    for s in range(20):
        out = step_fn(*state, x, y, jnp.float32(0.05), jnp.float32(s))
        state = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_fp8_gradients_track_fp32():
    # The custom-VJP quantized GEMM path must produce gradients aligned
    # with full-precision autodiff (cosine ≥ 0.85 per parameter) — the
    # property that makes FP8 training converge at all.
    params = model.init_params(3)
    x, y = make_batch(3, batch=16)
    g32 = jax.grad(lambda p: model.loss_fn(FP32_BASELINE, p, x, y))(params)
    g8 = jax.grad(
        lambda p: model.loss_fn(FP8_PAPER, p, x, y) * FP8_PAPER.loss_scale
    )(params)
    for (name, _), a, b in zip(model.param_specs(), g32, g8):
        a = np.asarray(a).ravel()
        b = np.asarray(b).ravel() / FP8_PAPER.loss_scale
        cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))
        assert cos > 0.85, (name, cos)


def test_fp8_train_step_runs_and_learns():
    step_fn = jax.jit(make_train_step(FP8_PAPER))
    params = model.init_params(3)
    moms = [jnp.zeros_like(p) for p in params]
    x, y = make_batch(3, batch=16)
    state = params + moms
    losses = []
    for s in range(25):
        out = step_fn(*state, x, y, jnp.float32(0.05), jnp.float32(s))
        state = list(out[:-1])
        losses.append(float(out[-1]))
    assert all(np.isfinite(losses)), losses
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses
    # Under the paper's scheme the master weights live on the FP16 grid.
    from compile.quant import NEAREST, quantize

    w = state[0]
    np.testing.assert_array_equal(np.asarray(w), np.asarray(quantize(w, FP16, NEAREST)))


def test_train_step_loss_is_unscaled():
    # The returned loss must be comparable across policies (scale divided
    # back out): both start near ln(10).
    x, y = make_batch(4)
    for policy in (FP32_BASELINE, FP8_PAPER):
        params = model.init_params(4)
        moms = [jnp.zeros_like(p) for p in params]
        out = jax.jit(make_train_step(policy))(
            *params, *moms, x, y, jnp.float32(0.0), jnp.float32(0.0)
        )
        assert 1.0 < float(out[-1]) < 6.0, (policy.name, float(out[-1]))


def test_fp8_first_layer_keeps_fp16_input_fidelity():
    # 133/128 grid values are FP16-exact but FP8-lossy; the first-layer
    # data operand must stay FP16 (§4.1).
    from compile.model import make_qgemm

    qg_first = make_qgemm(FP8_PAPER, "first")
    qg_mid = make_qgemm(FP8_PAPER, "middle")
    x = jnp.full((1, 1), 133.0 / 128.0, jnp.float32)
    w = jnp.ones((1, 1), jnp.float32)
    assert float(qg_first(x, w)[0, 0]) == 133.0 / 128.0
    assert float(qg_mid(x, w)[0, 0]) == 1.0
