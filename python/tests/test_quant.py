"""Tests for the normative quantizer (python/compile/quant.py) — including
a hypothesis sweep against an independent numpy bit-twiddling reference.
The same algorithm is implemented in Rust (numerics/format.rs); the
cross-language bit-equality check lives in rust/tests/cross_validation.rs,
which runs the AOT-lowered version of this code through PJRT."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.quant import (
    FP8,
    FP16,
    FP32,
    IEEE_HALF,
    NEAREST,
    STOCHASTIC,
    TRUNCATE,
    FloatFormat,
    quantize,
    quantize_sr,
)


def np_quantize_ref(x: np.ndarray, fmt: FloatFormat, mode: str = NEAREST) -> np.ndarray:
    """Independent numpy reference (deterministic modes), written against
    DESIGN.md §3 rather than ported from the jnp code."""
    out = np.empty_like(x, dtype=np.float32)
    for i, v in enumerate(np.asarray(x, dtype=np.float32).ravel()):
        u = np.float32(v).view(np.uint32)
        sign = -1.0 if (u >> 31) else 1.0
        e_field = (u >> 23) & 0xFF
        m_field = int(u & 0x7FFFFF)
        if e_field == 255:
            out.ravel()[i] = v if m_field else sign * fmt.max_normal
            continue
        if e_field == 0:
            out.ravel()[i] = sign * 0.0
            continue
        e = int(e_field) - 127
        shift = (23 - fmt.mbits) + max(fmt.emin - e, 0)
        if shift <= 0:
            out.ravel()[i] = np.float32(np.clip(v, -fmt.max_normal, fmt.max_normal))
            continue
        if shift > 26:
            out.ravel()[i] = sign * 0.0
            continue
        sig = (1 << 23) | m_field
        keep = sig >> shift
        rem = sig & ((1 << shift) - 1)
        if rem and mode == NEAREST:
            half = 1 << (shift - 1)
            if rem > half or (rem == half and keep & 1):
                keep += 1
        val = math.ldexp(keep, e - (23 - shift))
        val = min(val, fmt.max_normal)
        out.ravel()[i] = np.float32(sign * val)
    return out


FORMATS = [FP8, FP16, IEEE_HALF]


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f"e{f.ebits}m{f.mbits}")
@settings(max_examples=300, deadline=None)
@given(
    mant=st.floats(-4.0, 4.0, allow_nan=False),
    exp=st.integers(-40, 18),
)
def test_matches_numpy_reference(fmt, mant, exp):
    x = np.float32(mant * 2.0**exp)
    for mode in (NEAREST, TRUNCATE):
        got = np.asarray(quantize(jnp.float32(x), fmt, mode))
        want = np_quantize_ref(np.array([x]), fmt, mode)[0]
        assert got.tobytes() == want.tobytes(), (x, mode, got, want)


def test_paper_format_constants():
    assert FP8.bias == 15 and FP8.max_normal == 57344.0
    assert FP8.min_normal == 2.0**-14 and FP8.min_subnormal == 2.0**-16
    assert FP16.bias == 31 and FP16.emin == -30
    assert IEEE_HALF.max_normal == 65504.0


def test_known_values_fp8():
    xs = jnp.array([1.1, 1.125, 1.375, -1.2, 1e9, -1e9, 0.0], jnp.float32)
    got = np.asarray(quantize(xs, FP8, NEAREST))
    np.testing.assert_array_equal(
        got, np.array([1.0, 1.0, 1.5, -1.25, 57344, -57344, 0.0], np.float32)
    )


def test_specials():
    x = jnp.array([np.nan, np.inf, -np.inf, -0.0, 1e-40], jnp.float32)
    q = np.asarray(quantize(x, FP8, NEAREST))
    assert np.isnan(q[0])
    assert q[1] == 57344.0 and q[2] == -57344.0
    assert q[3] == 0.0 and np.signbit(q[3])
    assert q[4] == 0.0


@pytest.mark.parametrize("fmt", FORMATS, ids=lambda f: f"e{f.ebits}m{f.mbits}")
def test_idempotent(fmt):
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (2048,), jnp.float32, -100.0, 100.0)
    q1 = quantize(x, fmt, NEAREST)
    q2 = quantize(q1, fmt, NEAREST)
    assert np.asarray(q1).tobytes() == np.asarray(q2).tobytes()


def test_monotone_nearest():
    key = jax.random.PRNGKey(1)
    x = jnp.sort(jax.random.uniform(key, (4096,), jnp.float32, -50.0, 50.0))
    q = np.asarray(quantize(x, FP8, NEAREST))
    assert (np.diff(q) >= 0).all()


def test_fp32_identity():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (512,), jnp.float32) * 1e10
    assert np.asarray(quantize(x, FP32, NEAREST)).tobytes() == np.asarray(x).tobytes()


def test_stochastic_unbiased_and_two_neighbours():
    key = jax.random.PRNGKey(3)
    for x0, lo, hi in [(1.1, 1.0, 1.25), (3.3, 3.0, 3.5)]:
        q = np.asarray(quantize_sr(jnp.full((200_000,), x0, jnp.float32), FP8, key))
        assert set(np.unique(q)) <= {np.float32(lo), np.float32(hi)}
        assert abs(q.mean() - x0) < 0.002


def test_truncate_magnitude_never_increases():
    key = jax.random.PRNGKey(4)
    x = jax.random.uniform(key, (4096,), jnp.float32, -30.0, 30.0)
    q = np.asarray(quantize(x, FP8, TRUNCATE))
    assert (np.abs(q) <= np.abs(np.asarray(x)) + 1e-9).all()


def test_swamping_threshold_fp16():
    """§2.3: adding below-half-ulp values to a big FP16 accumulator is a
    no-op under nearest rounding (the swamping mechanism)."""
    big = jnp.float32(4096.0)  # ulp = 8
    assert float(quantize(big + 2.0, FP16, NEAREST)) == 4096.0
    assert float(quantize(big + 8.0, FP16, NEAREST)) == 4104.0
    # tie (half-ulp) to even
    assert float(quantize(big + 4.0, FP16, NEAREST)) == 4096.0
