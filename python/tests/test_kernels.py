"""L1 Pallas kernels vs the pure-jnp oracles (ref.py) — the CORE
correctness signal for the AOT path, including a hypothesis sweep over
GEMM shapes and chunk sizes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.axpy import sgd_axpy_pallas
from compile.kernels.gemm import chunked_gemm, vmem_bytes
from compile.kernels.quantize_k import quantize_pallas
from compile.kernels.ref import chunked_gemm_ref, quantize_fp8_ref, sgd_axpy_ref
from compile.quant import FP8, FP16, NEAREST, STOCHASTIC, quantize


def fp8_mat(key, m, n, lo=0.5, hi=1.5):
    return quantize_fp8_ref(jax.random.uniform(key, (m, n), jnp.float32, lo, hi))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 300),
    n=st.integers(1, 40),
    chunk=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_kernel_matches_ref_shapes(m, k, n, chunk, seed):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    a = fp8_mat(ka, m, k, -1.5, 1.5)
    b = fp8_mat(kb, k, n, -1.5, 1.5)
    got = np.asarray(chunked_gemm(a, b, chunk=chunk))
    want = np.asarray(chunked_gemm_ref(a, b, chunk=chunk))
    np.testing.assert_array_equal(got, want)


def test_gemm_kernel_block_boundaries():
    # Shapes exactly at / around the 128/64 block sizes.
    for m, k, n in [(128, 64, 128), (129, 65, 129), (127, 63, 1), (256, 512, 256)]:
        ka, kb = jax.random.split(jax.random.PRNGKey(m * 1000 + k + n))
        a = fp8_mat(ka, m, k)
        b = fp8_mat(kb, k, n)
        got = np.asarray(chunked_gemm(a, b))
        want = np.asarray(chunked_gemm_ref(a, b))
        np.testing.assert_array_equal(got, want, err_msg=f"{(m, k, n)}")


def test_gemm_kernel_close_to_f32_with_chunking():
    # Non-zero-mean operands, long K: chunked FP16 accumulation tracks f32.
    ka, kb = jax.random.split(jax.random.PRNGKey(7))
    a = fp8_mat(ka, 8, 8192)
    b = fp8_mat(kb, 8192, 8)
    exact = np.asarray(jnp.dot(a, b, preferred_element_type=jnp.float32))
    got = np.asarray(chunked_gemm(a, b, chunk=64))
    rel = np.abs(got - exact) / np.abs(exact)
    assert rel.max() < 0.01, rel.max()


def test_gemm_nochunk_swamps():
    # CL=1 (every product its own chunk): inter-chunk add16 swamps and the
    # result collapses far below the true sum — the Fig. 1(b)/5(a) failure.
    ka, kb = jax.random.split(jax.random.PRNGKey(8))
    a = fp8_mat(ka, 2, 32768)
    b = fp8_mat(kb, 32768, 2)
    exact = np.asarray(jnp.dot(a, b, preferred_element_type=jnp.float32))
    got = np.asarray(chunked_gemm_ref(a, b, chunk=1))
    assert (got < 0.25 * exact).all(), (got, exact)


def test_quantize_pallas_matches_quantize():
    key = jax.random.PRNGKey(9)
    x = jax.random.uniform(key, (10000,), jnp.float32, -60000.0, 60000.0)
    for fmt in (FP8, FP16):
        got = np.asarray(quantize_pallas(x, fmt, NEAREST))
        want = np.asarray(quantize(x, fmt, NEAREST))
        np.testing.assert_array_equal(got, want)


def test_quantize_pallas_stochastic_matches():
    key = jax.random.PRNGKey(10)
    x = jax.random.uniform(key, (5000,), jnp.float32, -10.0, 10.0)
    rbits = jax.random.bits(jax.random.PRNGKey(11), (5000,), jnp.uint32)
    got = np.asarray(quantize_pallas(x, FP8, STOCHASTIC, rbits))
    want = np.asarray(quantize(x, FP8, STOCHASTIC, rbits))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 9000), seed=st.integers(0, 2**31 - 1))
def test_axpy_kernel_matches_ref(n, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = jax.random.uniform(keys[0], (n,), jnp.float32, -2.0, 2.0)
    g = jax.random.uniform(keys[1], (n,), jnp.float32, -0.1, 0.1)
    v = jax.random.uniform(keys[2], (n,), jnp.float32, -0.5, 0.5)
    rb = jax.random.bits(keys[3], (3, n), jnp.uint32)
    w1, v1 = sgd_axpy_pallas(w, g, v, rb, 0.05, 0.9, 1e-4)
    w2, v2 = sgd_axpy_ref(w, g, v, 0.05, 0.9, 1e-4, rb)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_axpy_sr_moves_subulp_updates_in_expectation():
    # The Table 4 mechanism: sub-ulp updates survive under SR.
    n = 4096
    w = jnp.ones((n,), jnp.float32)
    v = jnp.zeros((n,), jnp.float32)
    steps = 500
    cur_w, cur_v = w, v
    key = jax.random.PRNGKey(12)
    for s in range(steps):
        key, sub = jax.random.split(key)
        rb = jax.random.bits(sub, (3, n), jnp.uint32)
        g = jnp.full((n,), 1e-4, jnp.float32)
        cur_w, cur_v = sgd_axpy_pallas(cur_w, g, cur_v, rb, 1.0, 0.0, 0.0)
    mean = float(cur_w.mean())
    assert abs(mean - (1.0 - steps * 1e-4)) < 0.01, mean


def test_vmem_budget():
    # DESIGN.md §11: ≤ 4 MiB per grid step at the default block shape.
    assert vmem_bytes() <= 4 << 20
