"""L1 Pallas kernel: the FP16 stochastic-rounding SGD weight update — the
three AXPYs of Fig. 2(b) fused into one elementwise pass (L2-Reg,
Momentum-Acc, Weight-Upd), each result re-rounded into FP16 with its own
uniform draw."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import FP16, STOCHASTIC, quantize

BLOCK = 4096


def _kernel(lr, momentum, weight_decay):
    def kernel(w_ref, g_ref, v_ref, r0_ref, r1_ref, r2_ref, wo_ref, vo_ref):
        w = w_ref[...]
        g2 = quantize(g_ref[...] + weight_decay * w, FP16, STOCHASTIC, r0_ref[...])
        v2 = quantize(momentum * v_ref[...] + g2, FP16, STOCHASTIC, r1_ref[...])
        vo_ref[...] = v2
        wo_ref[...] = quantize(w - lr * v2, FP16, STOCHASTIC, r2_ref[...])

    return kernel


@partial(jax.jit, static_argnames=("lr", "momentum", "weight_decay"))
def sgd_axpy_pallas(w, g, v, rbits3, lr: float, momentum: float, weight_decay: float):
    """Apply the fused FP16-SR update; returns (w', v').

    `rbits3` is `[3, n]` uint32 (one draw per element per AXPY), matching
    `ref.sgd_axpy_ref`.
    """
    n = w.shape[0]
    block = min(BLOCK, _next_pow2(n))
    rem = (-n) % block

    def pad(x):
        return jnp.pad(x, (0, rem)) if rem else x

    wp, gp, vp = pad(w), pad(g), pad(v)
    r0, r1, r2 = (pad(rbits3[i]) for i in range(3))
    grid = (wp.shape[0] // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    wo, vo = pl.pallas_call(
        _kernel(lr, momentum, weight_decay),
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(wp.shape, jnp.float32),
            jax.ShapeDtypeStruct(wp.shape, jnp.float32),
        ],
        interpret=True,
    )(wp, gp, vp, r0, r1, r2)
    return wo[:n], vo[:n]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
