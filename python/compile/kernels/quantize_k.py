"""L1 Pallas kernel: elementwise FP8/FP16 quantization (the representation
conversions of Fig. 2 — activations/weights/errors into FP8, Softmax input
into FP16). Pure VPU work; blocked so arbitrarily large tensors stream
through VMEM-sized tiles."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import NEAREST, STOCHASTIC, FloatFormat, quantize

BLOCK = 4096


@partial(jax.jit, static_argnames=("fmt", "mode"))
def quantize_pallas(x, fmt: FloatFormat, mode: str = NEAREST, rbits=None):
    """Quantize a 1-D (or flattened) array through the Pallas kernel."""
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = min(BLOCK, _next_pow2(n))
    rem = (-n) % block
    if rem:
        flat = jnp.pad(flat, (0, rem))
    grid = (flat.shape[0] // block,)

    if mode == STOCHASTIC:
        assert rbits is not None
        rflat = rbits.reshape(-1)
        if rem:
            rflat = jnp.pad(rflat, (0, rem))

        def kernel(x_ref, r_ref, o_ref):
            o_ref[...] = quantize(x_ref[...], fmt, STOCHASTIC, r_ref[...])

        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((block,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((block,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
            interpret=True,
        )(flat, rflat)
    else:

        def kernel(x_ref, o_ref):
            o_ref[...] = quantize(x_ref[...], fmt, mode)

        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
            out_specs=pl.BlockSpec((block,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
            interpret=True,
        )(flat)
    return out[:n].reshape(shape)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
