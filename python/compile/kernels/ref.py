"""Pure-jnp oracles for the Pallas kernels (and the cross-language
contract): the paper's reduced-precision dot product / GEMM (Fig. 3a) and
the FP16-SR weight-update AXPYs (Fig. 2b), at the same chunk-granularity
("fast") emulation fidelity as the Rust engine's default GEMM path.

Semantics (DESIGN.md §3):
- operands are FP8 values carried in f32; products are exact in f32,
- intra-chunk partial sums are computed in f32 and rounded into FP16 once
  per chunk,
- inter-chunk accumulation applies `add16` (quantize after every add).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..quant import FP8, FP16, NEAREST, STOCHASTIC, FloatFormat, quantize


def pad_to(x, axis: int, multiple: int):
    """Zero-pad `axis` of `x` up to the next multiple (zeros are exact)."""
    n = x.shape[axis]
    rem = (-n) % multiple
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("chunk",))
def chunked_gemm_ref(a, b, chunk: int = 64):
    """`C[M,N] = A[M,K] · B[K,N]` with chunk-based FP16 accumulation.

    Operands must already be quantized to the multiply format (FP8);
    the result equals the Rust `GemmPrecision::fp8_paper()` (fast) path up
    to f32 intra-chunk summation order.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    a = pad_to(a, 1, chunk)
    b = pad_to(b, 0, chunk)
    nc = a.shape[1] // chunk
    a3 = a.reshape(m, nc, chunk).transpose(1, 0, 2)  # [nc, M, CL]
    b3 = b.reshape(nc, chunk, n)  # [nc, CL, N]
    # Intra-chunk: exact f32 partials, one rounding into FP16 per chunk.
    partials = jnp.einsum("cmk,ckn->cmn", a3, b3, preferred_element_type=jnp.float32)
    partials = quantize(partials, FP16, NEAREST)

    # Inter-chunk: sequential add16.
    def step(acc, p):
        return quantize(acc + p, FP16, NEAREST), None

    out, _ = jax.lax.scan(step, jnp.zeros((m, n), jnp.float32), partials)
    return out


@jax.jit
def gemm_f32_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def quantize_fp8_ref(x):
    return quantize(x, FP8, NEAREST)


@partial(jax.jit, static_argnames=("fmt",))
def sgd_axpy_ref(w, g, v, lr, momentum, weight_decay, rbits3, fmt: FloatFormat = FP16):
    """The three FP16-SR AXPYs of Fig. 2(b) (rust: axpy.rs::sgd_update).

    `rbits3` is a `[3, n]` uint32 array: one draw per element per AXPY.
    """
    g2 = quantize(g + weight_decay * w, fmt, STOCHASTIC, rbits3[0])
    v2 = quantize(momentum * v + g2, fmt, STOCHASTIC, rbits3[1])
    w2 = quantize(w - lr * v2, fmt, STOCHASTIC, rbits3[2])
    return w2, v2
