"""L1 Pallas kernel: the paper's chunk-based reduced-precision GEMM.

Hardware adaptation (DESIGN.md §9): the paper's 14nm dataflow core feeds
FP8 products into FP16 chunk accumulators. On TPU the analogue is the
BlockSpec K-tiling — each grid step streams an `(bm, CL) × (CL, bn)` tile
pair HBM→VMEM, reduces it on the MXU in one shot (the *intra-chunk*
accumulation, CL = 64 matching both the paper's hardware sweet spot and
MXU-friendly K tiles), rounds the partial into FP16, and the sequential
K-grid dimension performs the *inter-chunk* `add16` into the revisited
output tile.

The kernel MUST run with `interpret=True` here: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. VMEM footprint and
MXU-utilization estimates live in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant import FP16, NEAREST, quantize
from .ref import pad_to

# Default block shape: 128×128 output tiles, CL=64 K-tiles →
# VMEM per step = (128·64 + 64·128 + 128·128) f32 ≈ 128 KiB ≪ 4 MiB budget.
BM, BN, CL = 128, 128, 64


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)
    # Intra-chunk: one MXU pass over the CL-length K tile, exact f32.
    partial_sum = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    # One rounding into the accumulation format per chunk.
    pq = quantize(partial_sum, FP16, NEAREST)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = pq

    @pl.when(k > 0)
    def _acc():
        # Inter-chunk add16: the FP16 accumulator register semantics.
        o_ref[...] = quantize(o_ref[...] + pq, FP16, NEAREST)


@partial(jax.jit, static_argnames=("chunk", "bm", "bn"))
def chunked_gemm(a, b, chunk: int = CL, bm: int = BM, bn: int = BN):
    """`C[M,N] = A[M,K] · B[K,N]`, FP8-valued operands (already quantized),
    FP16 chunk-based accumulation. Zero-pads every dimension to its block
    multiple (zeros are exact under quantization and contribute nothing)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = min(bm, _next_pow2(m))
    bn = min(bn, _next_pow2(n))
    a = pad_to(pad_to(a, 0, bm), 1, chunk)
    b = pad_to(pad_to(b, 0, chunk), 1, bn)
    mp, kp = a.shape
    np_ = b.shape[1]
    grid = (mp // bm, np_ // bn, kp // chunk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, chunk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((chunk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b)
    return out[:m, :n]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def vmem_bytes(bm: int = BM, bn: int = BN, chunk: int = CL) -> int:
    """Per-grid-step VMEM footprint estimate (f32 carriers; on real FP8/FP16
    hardware the A/B tiles shrink 4×/2×). Used by EXPERIMENTS.md §Perf."""
    return 4 * (bm * chunk + chunk * bn + bm * bn)
