"""AOT pipeline: lower every JAX/Pallas computation ONCE to HLO text.

Interchange is HLO **text** (not `.serialize()`d protos): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the Rust `xla` crate binds) rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Outputs under `artifacts/` (see rust/src/runtime/):

  cifar_cnn_{fp8,fp32}.hlo.txt        train_step(state..., x, y, lr, seed)
  cifar_cnn_{fp8,fp32}_fwd.hlo.txt    fwd(params..., x)
  cifar_cnn_{fp8,fp32}.manifest.txt   state shapes + meta (batch, classes)
  quant_fp8.hlo.txt                   Pallas quantize kernel, [4096] f32
  quant_fp16.hlo.txt
  gemm_fp8.hlo.txt                    Pallas chunked GEMM, [64,512]×[512,32]
  axpy_sr.hlo.txt                     Pallas FP16-SR SGD update, [4096]

Usage: python -m compile.aot --out ../artifacts/model.hlo.txt
(the Makefile target; `--out`'s directory is where everything lands).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.axpy import sgd_axpy_pallas
from .kernels.gemm import chunked_gemm
from .kernels.quantize_k import quantize_pallas
from .quant import FP8, FP16, NEAREST

BATCH = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def lower_model(outdir: str, policy: model.Policy) -> None:
    specs = model.param_specs()
    state = [f32(*s) for _, s in specs] * 2  # params then momentum
    x = f32(BATCH, *model.INPUT_SHAPE)
    y = f32(BATCH, model.CLASSES)
    lr = f32()
    seed = f32()

    tag = f"cifar_cnn_{policy.name}"
    step = jax.jit(model.make_train_step(policy))
    write(
        os.path.join(outdir, f"{tag}.hlo.txt"),
        to_hlo_text(step.lower(*state, x, y, lr, seed)),
    )
    fwd = jax.jit(model.make_fwd(policy))
    write(
        os.path.join(outdir, f"{tag}_fwd.hlo.txt"),
        to_hlo_text(fwd.lower(*[f32(*s) for _, s in specs], x)),
    )

    lines = []
    for kind in ("param", "mom"):
        for name, shape in specs:
            lines.append(f"{kind} {name} {','.join(str(d) for d in shape)}")
    lines.append(f"meta classes {model.CLASSES}")
    lines.append(f"meta batch {BATCH}")
    write(os.path.join(outdir, f"{tag}.manifest.txt"), "\n".join(lines) + "\n")


def lower_kernels(outdir: str) -> None:
    n = 4096
    # Elementwise quantize kernels (nearest — the bit-exact cross-language
    # contract; rust/tests/cross_validation.rs compares against the Rust
    # quantizer output for output).
    for fmt, name in ((FP8, "quant_fp8"), (FP16, "quant_fp16")):
        fn = jax.jit(lambda x, fmt=fmt: (quantize_pallas(x, fmt, NEAREST),))
        write(os.path.join(outdir, f"{name}.hlo.txt"), to_hlo_text(fn.lower(f32(n))))

    # Chunked GEMM kernel: FP8 operands, FP16 CL=64 accumulation.
    gemm = jax.jit(lambda a, b: (chunked_gemm(a, b, chunk=64),))
    write(
        os.path.join(outdir, "gemm_fp8.hlo.txt"),
        to_hlo_text(gemm.lower(f32(64, 512), f32(512, 32))),
    )

    # FP16-SR SGD AXPY kernel (lr/momentum/decay baked: the standalone
    # artifact is a micro-bench + cross-validation target; the train-step
    # artifact takes lr dynamically).
    axpy = jax.jit(
        lambda w, g, v, r: sgd_axpy_pallas(w, g, v, r, 0.05, 0.9, 1e-4)
    )
    write(
        os.path.join(outdir, "axpy_sr.hlo.txt"),
        to_hlo_text(axpy.lower(f32(n), f32(n), f32(n), u32(3, n))),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="sentinel output path; its directory receives all artifacts")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)

    lower_kernels(outdir)
    for policy in (model.FP32_BASELINE, model.FP8_PAPER):
        lower_model(outdir, policy)

    # The Makefile sentinel: points at the fp8 train step.
    src = os.path.join(outdir, "cifar_cnn_fp8.hlo.txt")
    with open(src) as f:
        write(os.path.abspath(args.out), f.read())


if __name__ == "__main__":
    main()
