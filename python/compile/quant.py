"""Bit-exact mirror of the Rust quantizer (rust/src/numerics/format.rs).

The algorithm is normative (DESIGN.md §3) and implemented operation-for-
operation identically on the f32 bit pattern; `rust/tests/cross_validation.rs`
executes the AOT-lowered version of this code through PJRT and asserts bit
equality with the Rust implementation on the deterministic rounding modes.

Everything here is pure jnp (usable under jit, grad-free) and shared by the
Pallas kernels, the L2 model, and the ref oracles.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

UINT = jnp.uint32
INT = jnp.int32


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """A reduced-precision format (1, ebits, mbits) with IEEE-like layout."""

    ebits: int
    mbits: int

    @property
    def bias(self) -> int:
        return (1 << (self.ebits - 1)) - 1

    @property
    def emax(self) -> int:
        return self.bias

    @property
    def emin(self) -> int:
        return 1 - self.bias

    @property
    def max_normal(self) -> float:
        return float((2.0 - 2.0 ** (-self.mbits)) * 2.0**self.emax)

    @property
    def min_normal(self) -> float:
        return float(2.0**self.emin)

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.emin - self.mbits))

    @property
    def width(self) -> int:
        return 1 + self.ebits + self.mbits


FP8 = FloatFormat(5, 2)  # the paper's (1,5,2)
FP16 = FloatFormat(6, 9)  # the paper's (1,6,9)
IEEE_HALF = FloatFormat(5, 10)
FP32 = FloatFormat(8, 23)

NEAREST = "nearest"
STOCHASTIC = "stochastic"
TRUNCATE = "truncate"


def _round_up(mode: str, keep, rem, shift, rbits):
    """The normative rounding decision (rust: rounding.rs::round_up)."""
    if mode == TRUNCATE:
        return jnp.zeros_like(keep, dtype=jnp.bool_)
    if mode == NEAREST:
        half = (UINT(1) << (shift - 1)).astype(UINT)
        return (rem > half) | ((rem == half) & ((keep & 1) == 1))
    if mode == STOCHASTIC:
        # shift ≤ 26 so rem + r < 2^27: no uint32 overflow.
        r = (rbits >> (UINT(32) - shift)).astype(UINT)
        return (rem + r) >= (UINT(1) << shift)
    raise ValueError(f"unknown rounding mode {mode!r}")


def _exact_pow2(e):
    """2^e as f32 by direct bit construction (XLA's exp2 can be off by an
    ulp, which would break bit-exactness with the Rust quantizer). `e` must
    be within the f32 normal range [-126, 127]."""
    bits = ((e + 127).astype(INT) << 23).astype(UINT)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


@partial(jax.jit, static_argnames=("fmt", "mode"))
def quantize(x, fmt: FloatFormat, mode: str = NEAREST, rbits=None):
    """Quantize f32 `x` to `fmt`, returning the representable value as f32.

    `rbits` supplies one uint32 of uniform bits per element for stochastic
    rounding (required iff mode == "stochastic").
    """
    if fmt.mbits >= 23 and fmt.ebits >= 8:
        return x  # fp32: identity
    if mode == STOCHASTIC:
        assert rbits is not None, "stochastic rounding needs rbits"
        rbits = rbits.astype(UINT)
    else:
        rbits = jnp.zeros_like(x, dtype=UINT)

    x = x.astype(jnp.float32)
    u = jax.lax.bitcast_convert_type(x, UINT)
    sign = u & UINT(0x8000_0000)
    e_field = ((u >> 23) & UINT(0xFF)).astype(INT)
    m_field = u & UINT(0x007F_FFFF)

    is_nan = (e_field == 255) & (m_field != 0)
    is_inf = (e_field == 255) & (m_field == 0)
    is_f32_subnormal = e_field == 0  # flush (below every target's range)

    e = e_field - 127
    emin = fmt.emin
    shift = (23 - fmt.mbits) + jnp.maximum(emin - e, 0)
    flush = shift > 26
    no_round = shift <= 0  # mantissa fits (can't happen for our formats)
    shift_c = jnp.clip(shift, 1, 26).astype(UINT)

    sig = (UINT(1) << 23) | m_field
    keep = sig >> shift_c
    rem = sig & ((UINT(1) << shift_c) - UINT(1))
    up = _round_up(mode, keep, rem, shift_c, rbits) & (rem != 0)
    keep = keep + up.astype(UINT)

    # Exact reconstruction: keep · 2^(e − (23 − shift)). The power of two is
    # built bit-exactly; exponents below the f32 normal floor (only possible
    # for 8-bit-exponent targets like bf16) are split into two exact
    # factors — the final value is a representable f32 (≤ mbits+1
    # significant bits above the target's min subnormal), so the last
    # multiply rounds exactly.
    e2 = e - (23 - shift)
    e_hi = jnp.clip(e2, -126, 127)
    e_lo = jnp.clip(e2 - e_hi, -126, 127)  # 0 unless deep-subnormal target
    val = keep.astype(jnp.float32) * _exact_pow2(e_hi) * _exact_pow2(e_lo)

    max_n = jnp.float32(fmt.max_normal)
    val = jnp.minimum(val, max_n)  # saturate
    signed = jax.lax.bitcast_convert_type(
        sign | jax.lax.bitcast_convert_type(val, UINT), jnp.float32
    )

    signed_zero = jax.lax.bitcast_convert_type(sign, jnp.float32)
    out = jnp.where(flush | is_f32_subnormal | (keep == 0), signed_zero, signed)
    out = jnp.where(no_round, jnp.clip(x, -max_n, max_n), out)
    out = jnp.where(is_inf, jnp.where(sign != 0, -max_n, max_n), out)
    out = jnp.where(is_nan, x, out)
    return out


def quantize_sr(x, fmt: FloatFormat, key):
    """Stochastic quantization drawing one uint32 per element from `key`."""
    rbits = jax.random.bits(key, shape=x.shape, dtype=UINT)
    return quantize(x, fmt, STOCHASTIC, rbits)


def add16(acc, x, fmt: FloatFormat = FP16, mode: str = NEAREST, rbits=None):
    """Reduced-precision addition: quantize the f32 sum into `fmt`
    (rust: softfloat.rs::add_rounded)."""
    return quantize(acc + x, fmt, mode, rbits)
