"""L2: the quantized CIFAR10-CNN training step in JAX.

Mirrors the Rust native engine's model (rust/src/nn/models/cifar_cnn.rs):
3 conv layers (5×5, ReLU, 2×2 maxpool) + 1 FC + 10-way Softmax, with the
paper's Fig. 2 precision plumbing:

- every Conv/FC GEMM is a `custom_vjp` whose Forward/Backward/Gradient
  GEMMs run the **L1 Pallas chunked-accumulation kernel** on FP8-quantized
  operands (FP16 first-layer data operand, FP16 last layer — §3/§4.1),
- the Softmax input is kept in FP16,
- the loss is scaled ×1000; the update divides it back out,
- the SGD update applies the FP16 stochastic-rounding AXPYs.

`aot.py` lowers `make_train_step` / `make_fwd` once to HLO text; the Rust
coordinator (`rust/src/runtime/engine.rs`) drives the executable with
device-resident state — Python never runs at training time.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .kernels.gemm import chunked_gemm
from .quant import FP8, FP16, FP32, NEAREST, STOCHASTIC, FloatFormat, quantize

# ---------------------------------------------------------------------------
# Precision policy (the L2 mirror of rust nn/quant.rs presets)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str
    gemm_fmt: FloatFormat  # operand format of middle-layer GEMMs
    gemm_last_fmt: FloatFormat  # operand format of the last layer
    input_fmt: FloatFormat  # first-layer data operand
    softmax_input_fmt: FloatFormat
    update_fmt: FloatFormat
    chunk: int
    loss_scale: float
    stochastic_update: bool

    @property
    def quantized(self) -> bool:
        return self.gemm_fmt.mbits < 23


FP8_PAPER = Policy(
    name="fp8",
    gemm_fmt=FP8,
    gemm_last_fmt=FP16,
    input_fmt=FP16,
    softmax_input_fmt=FP16,
    update_fmt=FP16,
    chunk=64,
    loss_scale=1000.0,
    stochastic_update=True,
)

FP32_BASELINE = Policy(
    name="fp32",
    gemm_fmt=FP32,
    gemm_last_fmt=FP32,
    input_fmt=FP32,
    softmax_input_fmt=FP32,
    update_fmt=FP32,
    chunk=64,
    loss_scale=1.0,
    stochastic_update=False,
)

POLICIES = {p.name: p for p in (FP8_PAPER, FP32_BASELINE)}

# ---------------------------------------------------------------------------
# Quantized GEMM with the Fig. 2 three-GEMM custom VJP
# ---------------------------------------------------------------------------


def make_qgemm(policy: Policy, pos: str):
    """Build `y[M,N] = x[M,K] @ w[N,K].T` with quantized fwd/bwd/grad GEMMs.

    `pos` ∈ {first, middle, last} selects the §4.1 exceptions.
    """
    wfmt = policy.gemm_last_fmt if pos == "last" else policy.gemm_fmt
    # First layer: data operand stays in the (wider) input format.
    afmt = policy.input_fmt if pos == "first" and policy.input_fmt.mbits > wfmt.mbits else wfmt
    efmt = wfmt

    def gemm(a, b):
        if not policy.quantized:
            return jnp.dot(a, b, preferred_element_type=jnp.float32)
        return chunked_gemm(a, b, chunk=policy.chunk)

    @jax.custom_vjp
    def qgemm(x, w):
        xq = quantize(x, afmt, NEAREST)
        wq = quantize(w, wfmt, NEAREST)
        return gemm(xq, wq.T)

    def fwd(x, w):
        return qgemm(x, w), (x, w)

    def bwd(res, dy):
        x, w = res
        # Tensors are stored quantized once and reused (DESIGN.md §3).
        xq = quantize(x, afmt, NEAREST)
        wq = quantize(w, wfmt, NEAREST)
        dyq = quantize(dy, efmt, NEAREST)
        dx = gemm(dyq, wq)  # Backward GEMM  [M,N]·[N,K]
        dw = gemm(dyq.T, xq)  # Gradient GEMM [N,M]·[M,K] — K = batch·spatial
        return dx, dw

    qgemm.defvjp(fwd, bwd)
    return qgemm


# ---------------------------------------------------------------------------
# CIFAR10-CNN forward pass
# ---------------------------------------------------------------------------

# (name, out_channels/features, kind) in parameter order — the contract
# aot.py's manifest and rust's init_state share.
LAYERS = [
    ("conv1", dict(in_c=3, out_c=16, k=5, pos="first")),
    ("conv2", dict(in_c=16, out_c=32, k=5, pos="middle")),
    ("conv3", dict(in_c=32, out_c=32, k=5, pos="middle")),
    ("fc", dict(in_f=32 * 4 * 4, out_f=10, pos="last")),
]
CLASSES = 10
INPUT_SHAPE = (3, 32, 32)


def param_specs():
    """[(name, shape)] in call-argument order."""
    specs = []
    for name, cfg in LAYERS:
        if name.startswith("conv"):
            specs.append((f"{name}.w", (cfg["out_c"], cfg["in_c"] * cfg["k"] * cfg["k"])))
            specs.append((f"{name}.b", (cfg["out_c"],)))
        else:
            specs.append((f"{name}.w", (cfg["out_f"], cfg["in_f"])))
            specs.append((f"{name}.b", (cfg["out_f"],)))
    return specs


def init_params(seed: int = 0):
    """Kaiming-normal weights / zero biases (mirrors rust init_state)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs():
        key, sub = jax.random.split(key)
        if len(shape) >= 2:
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            params.append(jax.random.normal(sub, shape, jnp.float32) * (2.0 / fan_in) ** 0.5)
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def _patches(x, k):
    """im2col: NCHW → [N·oh·ow, C·k·k] rows, SAME padding, stride 1.
    Feature order (c, ky, kx) matches rust tensor::im2col."""
    n = x.shape[0]
    p = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(k, k), window_strides=(1, 1), padding="SAME"
    )  # [N, C·k·k, oh, ow]
    ckk = p.shape[1]
    return p.transpose(0, 2, 3, 1).reshape(-1, ckk), n


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def forward(policy: Policy, params, x):
    """Logits for a NCHW batch."""
    qg = {name: make_qgemm(policy, cfg["pos"]) for name, cfg in LAYERS}
    it = iter(params)
    h = x
    for name, cfg in LAYERS[:3]:
        w, b = next(it), next(it)
        rows, n = _patches(h, cfg["k"])
        y = qg[name](rows, w) + b  # [N·oh·ow, oc]
        oh = h.shape[2]
        h = y.reshape(n, oh, oh, cfg["out_c"]).transpose(0, 3, 1, 2)
        h = _maxpool2(jnp.maximum(h, 0.0))
    w, b = next(it), next(it)
    h = h.reshape(h.shape[0], -1)
    return qg["fc"](h, w) + b


def ste_quantize(x, fmt: FloatFormat, mode: str = NEAREST):
    """Straight-through quantization: the value is quantized, the gradient
    passes through unchanged (quantize itself is built from bitcasts, whose
    autodiff is zero — the backward-path quantization of the error tensor
    is handled explicitly inside the qgemm custom VJP, exactly as the Rust
    engine hand-writes it)."""
    return x + jax.lax.stop_gradient(quantize(x, fmt, mode) - x)


def loss_fn(policy: Policy, params, x, y_onehot):
    logits = forward(policy, params, x)
    logits = ste_quantize(logits, policy.softmax_input_fmt, NEAREST)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


# ---------------------------------------------------------------------------
# SGD train step with FP16-SR updates
# ---------------------------------------------------------------------------

MOMENTUM = 0.9
WEIGHT_DECAY = 1e-4


def sgd_update(policy: Policy, params, moms, grads, lr, key):
    new_p, new_m = [], []
    for i, (w, v, g) in enumerate(zip(params, moms, grads)):
        decay = WEIGHT_DECAY if w.ndim >= 2 else 0.0
        if policy.update_fmt.mbits >= 23:
            g2 = g + decay * w
            v2 = MOMENTUM * v + g2
            w2 = w - lr * v2
        else:
            key, sub = jax.random.split(key)
            rb = jax.random.bits(sub, (3,) + w.shape, jnp.uint32)
            mode = STOCHASTIC if policy.stochastic_update else NEAREST
            g2 = quantize(g + decay * w, policy.update_fmt, mode, rb[0])
            v2 = quantize(MOMENTUM * v + g2, policy.update_fmt, mode, rb[1])
            w2 = quantize(w - lr * v2, policy.update_fmt, mode, rb[2])
        new_p.append(w2)
        new_m.append(v2)
        del i
    return new_p, new_m


def make_train_step(policy: Policy):
    """(params..., moms..., x, y_onehot, lr, seed) → (params', moms', loss).

    `seed` is a whole-valued f32 (exact < 2^24) folded into the threefry
    key for stochastic rounding — the Rust driver passes the step index.
    """
    k = len(param_specs())

    def train_step(*args):
        params = list(args[:k])
        moms = list(args[k : 2 * k])
        x, y_onehot, lr, seed = args[2 * k :]
        scaled = lambda p: loss_fn(policy, p, x, y_onehot) * policy.loss_scale
        loss_s, grads = jax.value_and_grad(scaled)(params)
        inv = 1.0 / policy.loss_scale
        grads = [g * inv for g in grads]
        key = jax.random.PRNGKey(seed.astype(jnp.int32))
        new_p, new_m = sgd_update(policy, params, moms, grads, lr, key)
        # Keep `seed` alive in every policy (the FP32 path never draws
        # bits; without this the lowered artifact would drop the argument
        # and the Rust driver's fixed 4-arg tail would mismatch).
        loss_out = loss_s * inv + seed * 0.0
        return tuple(new_p) + tuple(new_m) + (loss_out,)

    return train_step


def make_fwd(policy: Policy):
    """(params..., x) → (logits,)."""
    k = len(param_specs())

    def fwd(*args):
        return (forward(policy, list(args[:k]), args[k]),)

    return fwd
